"""Asyncio master: leases, heartbeats, failure detection, replica dispatch.

:class:`RuntimeMaster` is the live counterpart of the discrete-event
:class:`~repro.cluster.master.ClusterEngine`, and is written decision-for-
decision against it so the engine can replay its traces exactly:

* whole-cluster FIFO gang dispatch -- the next job starts only when no job
  is active and every alive worker is free; batch ``i % B`` goes to the
  i-th free worker in wid order, B resolved with the engine's precedence
  (``Job.plan.n_batches`` > scenario ``n_batches`` > alive count, clamped);
* cancel-on-earliest-cover -- when a batch's first replica finishes, its
  outstanding siblings (in wid order) are cancelled; the reclaimed time is
  ``scheduled_end - now`` against the replica's planned duration;
* rescue -- a worker dying with a batch's last replica queues the batch for
  re-dispatch to the lowest-wid free worker;
* failure detection -- a torn connection (EOF), a missed-heartbeat window,
  or a blown task lease all declare the worker dead at one stamped instant.

Every state transition is stamped once, on the strictly-increasing binary
grid of :class:`~repro.cluster.runtime.trace.TraceRecorder`, and appended to
the trace that :func:`~repro.cluster.runtime.trace.replay_trace` feeds back
through the engine.  Handlers mutate state without awaiting (sends are
buffered synchronously), so each recorded event is atomic and the recorded
order *is* the decision order.

:class:`Runtime` is the one-call facade: spawn workers (threads or real
subprocesses), run a workload under a
:class:`~repro.cluster.scenario.Scenario`, return a :class:`LiveReport`.
"""

from __future__ import annotations

import asyncio
import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Set, Tuple

from ..control import SpeculativePolicy
from ..master import JobRecord
from ..scenario import UNSET, Scenario, resolve_scenario
from ..scheduler import JobPlan
from .chaos import WIRE_DELAY, WIRE_DROP, WIRE_DUP, WIRE_PASS, FaultInjector
from .protocol import read_msg, send_nowait
from .trace import TICK, TraceRecorder, quantize, read_journal, trace_accounting
from .worker import spawn_worker_subprocess, spawn_worker_thread

__all__ = ["LiveJob", "LiveReport", "Runtime", "RuntimeMaster"]


@dataclasses.dataclass(frozen=True)
class LiveJob:
    """One live job: real task payloads instead of a service-time law.

    ``costs[i]`` is task i's nominal cost (seconds of sleep / compute);
    batch ``b`` of B executes tasks ``costs[b::B]``.  ``plan`` carries the
    same per-job :class:`~repro.cluster.scheduler.JobPlan` overrides the
    engine honours under the gang regime (``n_batches``,
    ``cancel_redundant``).  ``arrival`` is an offset in seconds from the
    run's start at which the job is submitted.
    """

    job_id: int
    costs: Tuple[float, ...]
    payload: str = "sleep"
    arrival: float = 0.0
    name: str = ""
    plan: Optional[JobPlan] = None
    # worker wid scales its real execution by (1 + wid * skew): cheap
    # stand-in for machines whose true speeds the master does not know --
    # the straggler spread that makes cancellation reclaim real time
    skew: float = 0.0

    @property
    def n_tasks(self) -> int:
        """How many tasks this job carries."""
        return len(self.costs)

    def batch_costs(self, batch: int, n_batches: int) -> Tuple[float, ...]:
        """Costs of the tasks landing in ``batch`` under a round-robin split into B."""
        return tuple(self.costs[batch::n_batches])


@dataclasses.dataclass
class LiveReport:
    """Outcome of one live run: the engine-report surface plus the trace."""

    records: List[JobRecord]
    worker_seconds: float
    cancelled_seconds_saved: float
    n_worker_failures: int
    n_replicas_rescued: int
    trace: tuple
    completion_order: Tuple[int, ...]
    n_speculative: int = 0
    n_task_failures: int = 0
    n_retries: int = 0
    # (job, batch, wid, traceback text) for every stamped task_fail -- the
    # evidence a raising payload surfaces to the caller; live-only detail,
    # deliberately outside accounting()
    task_errors: Tuple[Tuple[int, int, int, str], ...] = ()

    def accounting(self) -> dict:
        """Same key set as :meth:`~repro.cluster.master.EngineReport.accounting`."""
        return {
            "worker_seconds": float(self.worker_seconds),
            "cancelled_seconds_saved": float(self.cancelled_seconds_saved),
            "n_worker_failures": int(self.n_worker_failures),
            "n_replicas_rescued": int(self.n_replicas_rescued),
            "n_replans": 0,
            "n_speculative": int(self.n_speculative),
            "n_task_failures": int(self.n_task_failures),
            "n_retries": int(self.n_retries),
        }


@dataclasses.dataclass
class _LiveWorker:
    wid: int
    # None for the disconnected stubs a recovered master rebuilds from the
    # journal: the slot exists (its wid, epoch, and accounting history are
    # live) but nothing can be sent until a fresh worker re-joins it
    writer: Optional[asyncio.StreamWriter]
    pid: int
    alive: bool = True
    assignment: Optional[Tuple[int, int]] = None  # (job_id, batch)
    epoch: int = 0
    busy_since: float = 0.0
    scheduled_end: float = math.inf
    last_hb: float = 0.0  # raw monotonic, detection only
    lease_deadline: float = math.inf  # raw monotonic, detection only
    # latest heartbeat-reported progress fraction for the CURRENT assignment
    # (None until the worker proves it is actually executing the replica)
    progress: Optional[float] = None

    @property
    def free(self) -> bool:
        # a recovered stub (writer None) is not dispatchable until it re-joins
        return self.alive and self.assignment is None and self.writer is not None


@dataclasses.dataclass
class _LiveExec:
    job: LiveJob
    start: float
    n_batches: int
    replication: int
    cancel: bool
    done: Set[int] = dataclasses.field(default_factory=set)
    outstanding: Dict[int, Set[int]] = dataclasses.field(default_factory=dict)
    # completed sibling durations (the speculative policy's running median)
    # and the per-job backup budget consumed, mirroring the engine's _JobExec
    obs: List[float] = dataclasses.field(default_factory=list)
    spec_used: int = 0

    @property
    def complete(self) -> bool:
        return len(self.done) == self.n_batches


def _validate_runtime_scenario(sc: Scenario, n_workers: int) -> Scenario:
    """The runtime's slice of the one validation path.

    Shares :meth:`Scenario.validate` (live-backend rules, which admit
    ``retry`` and ``faults``), then rejects the simulation-only knobs: the
    live gang has real speeds and real churn, and space sharing / online
    replanning are not implemented yet.
    """
    sc.validate(n_workers=n_workers, backend="live")
    if sc.is_space:
        raise ValueError(
            "Scenario.scheduler/workers_per_job/job_plans: the live runtime "
            "runs the whole-cluster FIFO gang only (per-job plans ride on "
            "LiveJob.plan); space-sharing schedulers are simulation-only"
        )
    for knob in ("speeds", "churn", "churn_schedule", "replan"):
        if getattr(sc, knob) is not None:
            raise ValueError(
                f"Scenario.{knob}: simulation-only -- the live runtime "
                "measures real worker speeds and real failures"
            )
    return sc


class RuntimeMaster:
    """The asyncio master service.  See the module docstring for semantics.

    Lifecycle: ``await start()`` (returns the bound port), spawn workers at
    it, ``await wait_for_workers()``, ``await run(jobs)``, ``await close()``.

    With ``journal=`` every trace event is additionally appended (fsynced)
    to a JSONL write-ahead journal; after a crash,
    :meth:`RuntimeMaster.recover` rebuilds an equivalent master from that
    file and :meth:`resume` finishes the run with re-joined workers.
    """

    def __init__(
        self,
        n_workers: int,
        scenario: Optional[Scenario] = None,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        heartbeat_s: float = 0.05,
        heartbeat_timeout_s: float = 0.5,
        lease_factor: float = 8.0,
        lease_floor_s: float = 2.0,
        journal: Optional[str] = None,
        n_batches=UNSET,
        cancel_redundant=UNSET,
        speculation=UNSET,
        _resume_events: Optional[list] = None,
    ):
        sc = resolve_scenario(
            scenario,
            {
                "n_batches": n_batches,
                "cancel_redundant": cancel_redundant,
                "speculation": speculation,
            },
            where="RuntimeMaster",
        )
        self.scenario = _validate_runtime_scenario(sc, n_workers)
        self.n_workers = int(n_workers)
        self.host = host
        self._port_req = int(port)
        self.heartbeat_s = float(heartbeat_s)
        self.heartbeat_timeout_s = float(heartbeat_timeout_s)
        self.lease_factor = float(lease_factor)
        self.lease_floor_s = float(lease_floor_s)

        self.recorder = TraceRecorder(journal=journal, resume_events=_resume_events)
        if _resume_events is None:
            # first trace event: the originating scenario + worker budget, so
            # a trace file alone is replayable (replay_trace re-reads it when
            # the caller passes neither n_workers nor scenario)
            self.recorder.record(
                "scenario",
                self.recorder.stamp(),
                n_workers=self.n_workers,
                scenario=self.scenario.to_dict(),
            )
        self.workers: List[_LiveWorker] = []
        self.queue: List[LiveJob] = []
        self.active: Dict[int, _LiveExec] = {}
        self.rescue: List[Tuple[int, int]] = []
        self.records: List[JobRecord] = []
        self.completion_order: List[int] = []
        self._arrival_stamp: Dict[int, float] = {}

        self._ws = 0.0
        self._saved = 0.0
        self._n_failures = 0
        self._n_rescued = 0
        self._n_spec = 0
        self._n_task_failures = 0
        self._n_retries = 0
        self.task_errors: List[Tuple[int, int, int, str]] = []
        self._spec_policy = (
            SpeculativePolicy(self.scenario.speculation)
            if self.scenario.speculation is not None
            else None
        )
        # retry machinery (mirrors ClusterEngine): attempts per (job, batch),
        # armed backoff entries (release, seq, job, batch, attempt), and the
        # batches whose next rescue-dispatch is a retry (for counting)
        self._attempts: Dict[Tuple[int, int], int] = {}
        self._pending_retries: List[Tuple[float, int, int, int, int]] = []
        self._retry_seq = 0
        self._retry_batches: Set[Tuple[int, int]] = set()
        self._chaos = FaultInjector(self.scenario.faults) if self.scenario.faults else None
        self._n_jobs_expected = 0
        self._finalized = False
        self._crashed = False
        self._server: Optional[asyncio.base_events.Server] = None
        self._watchdog_task: Optional[asyncio.Task] = None
        self._spec_task: Optional[asyncio.Task] = None
        self._chaos_task: Optional[asyncio.Task] = None
        self._all_joined = asyncio.Event()
        self._done = asyncio.Event()
        self._ran = False
        self._recovered = _resume_events is not None
        if _resume_events is not None:
            self._rebuild(_resume_events)

    # -- lifecycle -----------------------------------------------------------

    async def start(self) -> int:
        """Bind the socket, arm the background loops, return the bound port."""
        self._server = await asyncio.start_server(self._handle_conn, self.host, self._port_req)
        self.port = self._server.sockets[0].getsockname()[1]
        self._watchdog_task = asyncio.ensure_future(self._watchdog())
        if self._spec_policy is not None:
            self._spec_task = asyncio.ensure_future(self._spec_loop())
        if self._chaos is not None:
            self._chaos_task = asyncio.ensure_future(self._chaos_loop())
        return self.port

    async def wait_for_workers(self, timeout_s: float = 30.0) -> None:
        """Block until every expected worker has joined."""
        await asyncio.wait_for(self._all_joined.wait(), timeout_s)

    async def run(self, jobs: Sequence[LiveJob], timeout_s: float = 120.0) -> LiveReport:
        """Submit ``jobs`` at their arrival offsets and run to completion."""
        if self._ran:
            raise RuntimeError("RuntimeMaster.run() is single-shot; construct a new master")
        if self._recovered:
            raise RuntimeError("a recovered master resumes its journaled jobs: call resume()")
        self._ran = True
        self._n_jobs_expected = len(jobs)
        if not jobs:
            self._finalize(self.recorder.stamp())
        for job in sorted(jobs, key=lambda j: (j.arrival, j.job_id)):
            delay = job.arrival - self.recorder.elapsed()
            if delay > 0:
                await asyncio.sleep(delay)
            self._on_submit(job)
        await asyncio.wait_for(self._done.wait(), timeout_s)
        return self._report()

    async def resume(self, timeout_s: float = 120.0) -> LiveReport:
        """Finish a recovered run: re-arm the backoff timers that were in
        flight at the crash and wait for the journaled jobs to complete.
        Call after ``start()`` (workers re-join the recovered wids and pick
        up the rescue backlog the crash left behind).
        """
        if not self._recovered:
            raise RuntimeError("resume() only applies to RuntimeMaster.recover() masters")
        if self._ran:
            raise RuntimeError("RuntimeMaster.resume() is single-shot")
        self._ran = True
        loop = asyncio.get_running_loop()
        for entry in list(self._pending_retries):
            loop.call_later(max(0.0, entry[0] - self.recorder.elapsed()), self._fire_retry, entry)
        if not self._finalized and len(self.records) == self._n_jobs_expected:
            self._finalize(self.recorder.stamp())
        await asyncio.wait_for(self._done.wait(), timeout_s)
        return self._report()

    def _report(self) -> LiveReport:
        return LiveReport(
            records=sorted(self.records, key=lambda r: r.job_id),
            worker_seconds=self._ws,
            cancelled_seconds_saved=self._saved,
            n_worker_failures=self._n_failures,
            n_replicas_rescued=self._n_rescued,
            trace=self.recorder.events,
            completion_order=tuple(self.completion_order),
            n_speculative=self._n_spec,
            n_task_failures=self._n_task_failures,
            n_retries=self._n_retries,
            task_errors=tuple(self.task_errors),
        )

    async def close(self) -> None:
        """Orderly shutdown: cancel loops, wave workers off, close the journal."""
        for t in (self._watchdog_task, self._spec_task, self._chaos_task):
            if t is not None:
                t.cancel()
        for w in self.workers:
            if w.writer is None:
                continue
            try:
                send_nowait(w.writer, {"type": "shutdown"})
            except (ConnectionError, RuntimeError):
                pass
            w.writer.close()
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        self.recorder.close_journal()

    async def crash(self) -> None:
        """Die abruptly, as a real master crash would: no shutdown frames, no
        finalize, no flush accounting -- just torn sockets and a journal that
        ends mid-run.  The chaos harness's stand-in for ``kill -9`` on the
        master process; :meth:`recover` rebuilds from the journal.
        """
        self._crashed = True
        self._pending_retries.clear()  # armed timers no-op via membership check
        for t in (self._watchdog_task, self._spec_task, self._chaos_task):
            if t is not None:
                t.cancel()
        for w in self.workers:
            if w.writer is not None:
                w.writer.close()
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        self.recorder.close_journal()

    # -- connection handling -------------------------------------------------

    async def _handle_conn(self, reader, writer) -> None:
        msg = await read_msg(reader)
        if msg is None or msg.get("type") != "register":
            writer.close()
            return
        worker = self._grant_registration(writer, int(msg.get("pid", -1)))
        if worker is None:
            writer.close()
            return
        while True:
            msg = await read_msg(reader)
            if worker.writer is not writer:
                # this connection's registration was retired by a re-join:
                # whatever the stale socket still delivers (late heartbeats,
                # its eventual EOF) must not touch the fresh registration
                writer.close()
                return
            if msg is None:
                self._fail(worker, "eof")
                return
            if self._chaos is not None and not (self._finalized or self._crashed):
                if msg["type"] == "hb":
                    # a stalled window swallows heartbeats wholesale (before
                    # the wire layer -- the stall models the worker not
                    # sending, not the network losing frames)
                    win = self._chaos.stalled_window(worker.wid, self.recorder.elapsed())
                    if win is not None:
                        if self._chaos.stall_needs_stamp(win):
                            self.recorder.record(
                                "chaos",
                                self.recorder.stamp(),
                                kind="hb_stall",
                                wid=worker.wid,
                                window=win,
                            )
                        continue
                verdict = self._chaos.wire("in")
                if verdict != WIRE_PASS:
                    self.recorder.record(
                        "chaos",
                        self.recorder.stamp(),
                        kind=verdict,
                        dir="in",
                        wid=worker.wid,
                        msg=msg["type"],
                    )
                    if verdict == WIRE_DROP:
                        continue
                    if verdict == WIRE_DELAY:
                        asyncio.get_running_loop().call_later(
                            self._chaos.plan.delay_s, self._process_frame, worker, writer, msg
                        )
                        continue
                    self._process_frame(worker, writer, msg)  # dup: extra copy
            self._process_frame(worker, writer, msg)

    def _process_frame(self, worker: _LiveWorker, writer, msg: dict) -> None:
        """Apply one inbound frame.  Separated from the read loop so the
        chaos layer can duplicate or delay delivery; the ``writer`` identity
        guard keeps delayed frames from a retired connection away from a
        re-joined registration."""
        if self._crashed or worker.writer is not writer:
            return
        kind = msg["type"]
        if kind == "hb":
            if not worker.alive:
                return
            worker.last_hb = time.monotonic()
            if (
                worker.assignment is not None
                and msg.get("job") == worker.assignment[0]
                and msg.get("batch") == worker.assignment[1]
                and msg.get("epoch") == worker.epoch
            ):
                worker.progress = float(msg.get("frac", 0.0))
        elif kind == "finish":
            self._on_finish(worker, msg)
        elif kind == "fail":
            self._on_task_fail(worker, msg)

    def _grant_registration(self, writer, pid: int) -> Optional[_LiveWorker]:
        """Admit a registering connection: fresh wid, re-joined slot, or None.

        Below the worker budget, registrations fill fresh wids exactly as
        before.  At budget, a new connection may *re-join*: if some worker
        is dead, its stale registration is retired (socket closed at failure
        time, epoch already bumped so in-flight messages stay stale) and its
        wid granted to the newcomer, which becomes dispatchable immediately
        -- pending rescues first, then the gang, like any capacity gain.
        The re-join is stamped as a ``join`` event, which
        :func:`~repro.cluster.runtime.trace.replay_trace` feeds to the
        engine as an up-transition on the shared churn timeline, so the
        digital twin replays the recovery exactly.  Registrations after the
        run finalized (or with every wid alive) are refused.
        """
        if self._finalized:
            return None
        if len(self.workers) < self.n_workers:
            worker = _LiveWorker(
                wid=len(self.workers),
                writer=writer,
                pid=pid,
                last_hb=time.monotonic(),
            )
            self.workers.append(worker)
            self.recorder.record("join", self.recorder.stamp(), wid=worker.wid, pid=worker.pid)
            send_nowait(writer, self._welcome(worker.wid))
            if len(self.workers) == self.n_workers:
                self._all_joined.set()
            return worker
        worker = next((w for w in self.workers if not w.alive), None)
        if worker is None:
            return None
        worker.writer = writer
        worker.pid = pid
        worker.alive = True
        worker.assignment = None
        worker.scheduled_end = math.inf
        worker.lease_deadline = math.inf
        worker.progress = None
        worker.last_hb = time.monotonic()
        now = self.recorder.stamp()
        self.recorder.record("join", now, wid=worker.wid, pid=worker.pid)
        send_nowait(writer, self._welcome(worker.wid))
        if all(w.alive and w.writer is not None for w in self.workers):
            self._all_joined.set()  # a recovered master's full complement re-joined
        self._assign_rescues(now)
        self._try_dispatch(now)
        return worker

    def _welcome(self, wid: int) -> dict:
        return {
            "type": "welcome",
            "wid": wid,
            "heartbeat_s": self.heartbeat_s,
            # seed the worker-side heartbeat jitter deterministically per plan
            "hb_seed": self.scenario.faults.seed if self.scenario.faults is not None else 0,
        }

    async def _watchdog(self) -> None:
        """Missed-heartbeat and blown-lease detection."""
        period = max(self.heartbeat_timeout_s / 4.0, 0.01)
        while True:
            await asyncio.sleep(period)
            now_m = time.monotonic()
            for w in self.workers:
                if not w.alive:
                    continue
                if now_m - w.last_hb > self.heartbeat_timeout_s:
                    self._fail(w, "heartbeat")
                elif w.assignment is not None and now_m > w.lease_deadline:
                    self._fail(w, "lease")

    async def _chaos_loop(self) -> None:
        """Deliver the FaultPlan's scheduled kills: tear the victim's
        connection (the read loop then fails it with cause ``eof``, exactly
        like a real worker death).  Each delivery is stamped as a ``chaos``
        event so recovery never re-kills."""
        while True:
            await asyncio.sleep(0.01)
            if self._finalized or self._crashed:
                continue
            for wid in self._chaos.due_kills(self.recorder.elapsed()):
                w = self.workers[wid] if wid < len(self.workers) else None
                if w is None:
                    continue  # not yet joined; retry next tick
                if not w.alive or w.writer is None:
                    self._chaos.mark_killed(wid)  # already dead: kill is a no-op
                    continue
                self._chaos.mark_killed(wid)
                self.recorder.record("chaos", self.recorder.stamp(), kind="kill", wid=wid)
                w.writer.close()

    # -- speculative backups (reactive replication, engine-aligned) ----------

    async def _spec_loop(self) -> None:
        """Heartbeat-epoch timer for the speculative policy: every interval,
        look for a laggard and back at most one up (one stamped launch per
        firing, the engine's rule)."""
        interval = self.scenario.speculation.interval
        while True:
            await asyncio.sleep(interval)
            if not self._finalized:
                self._spec_check()

    def _spec_check(self) -> None:
        """Launch at most ONE backup: the first lagging (job, batch) in
        sorted order, on the lowest-wid free worker -- decision-for-decision
        the engine's ``_on_spec_check``, evaluated at one grid stamp so
        :func:`~repro.cluster.runtime.trace.replay_trace` can feed the stamp
        to the engine as a scripted ``speculation_times`` epoch and re-derive
        the identical launch.

        On top of the engine's policy the live master demands *partial
        progress*: every outstanding replica of the laggard must have
        heartbeat-reported progress on its current assignment.  A replica
        that never reported is the failure detector's problem, not the
        speculator's.  The gate only suppresses a launch (no stamp, so the
        replay never checks it); it can never redirect one, which is what
        keeps the scripted replay exact.
        """
        cfg, pol = self.scenario.speculation, self._spec_policy
        now = self.recorder.stamp()
        for job_id in sorted(self.active):
            jexec = self.active[job_id]
            if jexec.spec_used >= cfg.max_backups:
                continue
            med = pol.median(jexec.obs)
            if med is None:
                continue
            for batch in sorted(jexec.outstanding):
                wids = jexec.outstanding[batch]
                if batch in jexec.done or not wids:
                    continue
                y = max(self.workers[w].busy_since for w in wids)
                if not pol.lagging(now - y, med):
                    continue
                if any(self.workers[w].progress is None for w in wids):
                    return  # laggard found but unproven: no launch this epoch
                free = self._free_workers()
                if not free:
                    return
                jexec.spec_used += 1
                self._n_spec += 1
                self._assign(free[0], jexec, batch, now, rescue=False, spec=True)
                return

    # -- plan resolution (the engine's precedence, verbatim) -----------------

    def _choose_B(self, job: LiveJob, n_avail: int) -> int:
        if job.plan is not None and job.plan.n_batches is not None:
            b = job.plan.n_batches
        elif self.scenario.n_batches is not None:
            b = self.scenario.n_batches
        else:
            b = n_avail
        return max(1, min(int(b), n_avail))

    def _job_cancel(self, job: LiveJob) -> bool:
        if job.plan is not None and job.plan.cancel_redundant is not None:
            return bool(job.plan.cancel_redundant)
        return self.scenario.cancel_redundant

    # -- event handlers (one stamp each, mirroring the engine) ---------------

    def _on_submit(self, job: LiveJob) -> None:
        now = self.recorder.stamp()
        plan = None
        if job.plan is not None:
            plan = {
                "workers": job.plan.workers,
                "n_batches": job.plan.n_batches,
                "cancel_redundant": job.plan.cancel_redundant,
            }
        self.recorder.record(
            "submit",
            now,
            job=job.job_id,
            n_tasks=job.n_tasks,
            plan=plan,
            name=job.name,
            # the full job definition rides on the journal so recover() can
            # re-dispatch work the crash left queued or in flight
            costs=list(job.costs),
            payload=job.payload,
            skew=job.skew,
        )
        self._arrival_stamp[job.job_id] = now
        self.queue.append(job)
        self._assign_rescues(now)
        self._try_dispatch(now)

    def _on_finish(self, worker: _LiveWorker, msg: dict) -> None:
        job_id, batch = int(msg["job"]), int(msg["batch"])
        if (
            self._finalized
            or not worker.alive
            or int(msg["epoch"]) != worker.epoch
            or worker.assignment != (job_id, batch)
        ):
            return  # stale: cancelled, superseded, or the run already ended
        now = self.recorder.stamp()
        self.recorder.record("finish", now, wid=worker.wid, job=job_id, batch=batch)
        self._release(worker, now)
        jexec = self.active.get(job_id)
        if jexec is None:
            # the job already covered; this straggler ran to completion
            self._assign_rescues(now)
            self._try_dispatch(now)
            return
        jexec.outstanding[batch].discard(worker.wid)
        if batch not in jexec.done:
            jexec.done.add(batch)
            # the batch's first completion is a sibling-duration observation
            # for the speculative policy's running median (engine-identical:
            # grid-stamped finish minus grid-stamped dispatch)
            jexec.obs.append(now - worker.busy_since)
            if jexec.cancel:
                for sib_wid in sorted(jexec.outstanding[batch]):
                    self._cancel_replica(self.workers[sib_wid], now)
                jexec.outstanding[batch].clear()
            if jexec.complete:
                self._finish_job(jexec, now)
        if not self._finalized:
            self._assign_rescues(now)
            self._try_dispatch(now)

    def _fail(self, worker: _LiveWorker, cause: str) -> None:
        if self._finalized or not worker.alive:
            return
        now = self.recorder.stamp()
        self.recorder.record("fail", now, wid=worker.wid, cause=cause)
        self._n_failures += 1
        if worker.assignment is not None:
            job_id, batch = worker.assignment
            self._ws += now - worker.busy_since
            jexec = self.active.get(job_id)
            if jexec is not None:
                jexec.outstanding[batch].discard(worker.wid)
                if batch not in jexec.done and not jexec.outstanding[batch]:
                    self.rescue.append((job_id, batch))
            worker.assignment = None
            worker.scheduled_end = math.inf
        worker.alive = False
        worker.epoch += 1
        if worker.writer is not None:  # recovery's crash-fail has no socket
            worker.writer.close()
        self._assign_rescues(now)
        self._try_dispatch(now)

    # -- task failure, retry, abandonment (mirroring the engine) -------------

    def _on_task_fail(self, worker: _LiveWorker, msg: dict) -> None:
        """A ``fail`` frame: the payload raised on the worker.  The replica is
        released (its worker-seconds are real and spent); if the batch is
        still wanted, the retry budget arms a backoff timer, and when the
        budget is exhausted with nothing else in flight the job is abandoned
        (recorded with ``finish=inf``), the engine's rule exactly."""
        job_id, batch = int(msg["job"]), int(msg["batch"])
        if (
            self._finalized
            or not worker.alive
            or int(msg["epoch"]) != worker.epoch
            or worker.assignment != (job_id, batch)
        ):
            return
        now = self.recorder.stamp()
        self._n_task_failures += 1
        err = str(msg.get("error", ""))[:2000]
        self.task_errors.append((job_id, batch, worker.wid, err))
        self._release(worker, now)
        jexec = self.active.get(job_id)
        attempt = 0
        if jexec is not None and batch not in jexec.done:
            attempt = self._attempts.get((job_id, batch), 0) + 1
            self._attempts[(job_id, batch)] = attempt
        self.recorder.record(
            "task_fail", now, wid=worker.wid, job=job_id, batch=batch, attempt=attempt, error=err
        )
        if jexec is not None:
            jexec.outstanding[batch].discard(worker.wid)
            if batch not in jexec.done:
                retry = self.scenario.retry
                if retry is not None and attempt <= retry.max_attempts:
                    self._retry_seq += 1
                    entry = (now + retry.backoff(attempt), self._retry_seq, job_id, batch, attempt)
                    self._pending_retries.append(entry)
                    asyncio.get_running_loop().call_later(
                        max(0.0, entry[0] - self.recorder.elapsed()), self._fire_retry, entry
                    )
                elif not jexec.outstanding[batch] and not any(
                    j == job_id and b == batch for _, _, j, b, _ in self._pending_retries
                ):
                    self._abandon_job(jexec, now)
        if not self._finalized:
            self._assign_rescues(now)
            self._try_dispatch(now)

    def _fire_retry(self, entry: Tuple[float, int, int, int, int]) -> None:
        """Backoff timer fired: release the batch into the rescue queue and
        stamp a ``retry`` event (the stamp is what the engine's scripted
        ``retry_times`` consumes on replay).  Timers fire in (release, seq)
        order, matching the engine's min-heap pop of pending retries."""
        if entry not in self._pending_retries:
            return  # consumed by recovery rebuild, finalize, or job teardown
        self._pending_retries.remove(entry)
        if self._finalized or self._crashed:
            return
        _release_t, _seq, job_id, batch, attempt = entry
        jexec = self.active.get(job_id)
        if jexec is None or batch in jexec.done:
            return
        now = self.recorder.stamp()
        self.recorder.record("retry", now, job=job_id, batch=batch, attempt=attempt)
        self._retry_batches.add((job_id, batch))
        self.rescue.append((job_id, batch))
        self._assign_rescues(now)
        self._try_dispatch(now)

    def _abandon_job(self, jexec: _LiveExec, now: float) -> None:
        """Retry budget exhausted with no replica left in flight: the job
        fails permanently.  Recorded with ``finish=inf`` so makespan summaries
        are poisoned rather than silently truncated."""
        job = jexec.job
        self.records.append(
            JobRecord(
                job_id=job.job_id,
                name=job.name,
                arrival=self._arrival_stamp[job.job_id],
                start=jexec.start,
                finish=math.inf,
                n_batches=jexec.n_batches,
                replication=jexec.replication,
            )
        )
        self.completion_order.append(job.job_id)
        self.recorder.record(
            "job_fail",
            now,
            job=job.job_id,
            start=jexec.start,
            n_batches=jexec.n_batches,
            replication=jexec.replication,
        )
        del self.active[job.job_id]
        self._drop_retry_state(job.job_id)
        if len(self.records) == self._n_jobs_expected:
            self._finalize(now)

    def _drop_retry_state(self, job_id: int) -> None:
        self.rescue = [(j, b) for (j, b) in self.rescue if j != job_id]
        self._pending_retries = [e for e in self._pending_retries if e[2] != job_id]
        self._retry_batches = {(j, b) for (j, b) in self._retry_batches if j != job_id}

    # -- dispatch (the engine's gang loop, verbatim) -------------------------

    def _free_workers(self) -> List[_LiveWorker]:
        return [w for w in self.workers if w.free]  # wid order by construction

    def _try_dispatch(self, now: float) -> None:
        while self.queue and not self.active:
            n_alive = sum(1 for w in self.workers if w.alive)
            free = self._free_workers()
            if n_alive == 0 or len(free) < n_alive:
                return
            job = self.queue.pop(0)
            b = self._choose_B(job, n_alive)
            r = n_alive // b
            jexec = _LiveExec(
                job=job,
                start=now,
                n_batches=b,
                replication=r,
                cancel=self._job_cancel(job),
            )
            self.active[job.job_id] = jexec
            # journaled before its dispatches so recover() can rebuild the
            # execution (B, r, cancel are derived from the *crashed* master's
            # alive count, which the recovered one must honour); replay and
            # the accounting fold ignore it
            self.recorder.record(
                "job_start",
                now,
                job=job.job_id,
                n_batches=b,
                replication=r,
                cancel=jexec.cancel,
            )
            for idx, worker in enumerate(free[: b * r]):
                self._assign(worker, jexec, idx % b, now, rescue=False)

    def _assign_rescues(self, now: float) -> None:
        while self.rescue:
            free = self._free_workers()
            if not free:
                return
            job_id, batch = self.rescue.pop(0)
            jexec = self.active.get(job_id)
            if jexec is None or batch in jexec.done:
                continue
            retry = (job_id, batch) in self._retry_batches
            self._retry_batches.discard((job_id, batch))
            self._assign(free[0], jexec, batch, now, rescue=True, retry=retry)
            if retry:
                self._n_retries += 1
            else:
                self._n_rescued += 1

    def _assign(
        self,
        worker: _LiveWorker,
        jexec: _LiveExec,
        batch: int,
        now: float,
        *,
        rescue: bool,
        spec: bool = False,
        retry: bool = False,
    ) -> None:
        costs = jexec.job.batch_costs(batch, jexec.n_batches)
        # per-replica expectation: the master schedules with the worker's
        # speed factor (it would measure one on a real cluster), so a batch's
        # replicas get distinct scheduled ends -- the slack that cancellation
        # reclaims and that lease deadlines must respect
        planned = quantize(sum(costs) * (1.0 + worker.wid * jexec.job.skew))
        worker.assignment = (jexec.job.job_id, batch)
        worker.busy_since = now
        worker.scheduled_end = now + planned
        worker.progress = None
        worker.lease_deadline = time.monotonic() + max(
            self.lease_floor_s, planned * self.lease_factor
        )
        jexec.outstanding.setdefault(batch, set()).add(worker.wid)
        self.recorder.record(
            "dispatch",
            now,
            wid=worker.wid,
            job=jexec.job.job_id,
            batch=batch,
            planned=planned,
            rescue=rescue,
            spec=spec,
            retry=retry,
        )
        frame = {
            "type": "task",
            "job": jexec.job.job_id,
            "batch": batch,
            "epoch": worker.epoch,
            "payload": jexec.job.payload,
            "costs": list(costs),
            "skew": jexec.job.skew,
            "lease_s": max(self.lease_floor_s, planned * self.lease_factor),
        }
        if self._chaos is not None:
            # dispatch-time chaos rides on the frame itself: the slowdown only
            # stretches real execution (the trace's finish stamp captures it),
            # while an injected raise is journaled so recovery keeps the
            # delivered-raises count
            factor = self._chaos.slow_factor(worker.wid, now)
            if factor != 1.0:
                frame["chaos_factor"] = factor
            if self._chaos.payload_raise(jexec.job.job_id, batch):
                frame["chaos_raise"] = True
                self.recorder.record(
                    "chaos", now, kind="raise", job=jexec.job.job_id, batch=batch
                )
        self._send(worker, frame)

    def _send(self, worker: _LiveWorker, frame: dict) -> None:
        """Outbound frames pass the wire-chaos layer (task/cancel only --
        registration traffic stays reliable, or nothing could ever join)."""
        if worker.writer is None:
            return
        if self._chaos is not None and not (self._finalized or self._crashed):
            verdict = self._chaos.wire("out")
            if verdict != WIRE_PASS:
                self.recorder.record(
                    "chaos",
                    self.recorder.stamp(),
                    kind=verdict,
                    dir="out",
                    wid=worker.wid,
                    msg=frame["type"],
                )
                if verdict == WIRE_DROP:
                    return
                if verdict == WIRE_DELAY:
                    asyncio.get_running_loop().call_later(
                        self._chaos.plan.delay_s,
                        self._deliver_later,
                        worker,
                        frame,
                        worker.epoch,
                    )
                    return
                self._send_raw(worker, frame)  # dup: extra copy
        self._send_raw(worker, frame)

    def _send_raw(self, worker: _LiveWorker, frame: dict) -> None:
        if worker.writer is None:
            return
        try:
            send_nowait(worker.writer, frame)
        except (ConnectionError, RuntimeError, OSError):
            pass  # torn transport: failure detection owns this worker now

    def _deliver_later(self, worker: _LiveWorker, frame: dict, epoch: int) -> None:
        # a delayed frame is dropped if its addressee's registration moved on
        # (failed, cancelled, re-joined): the dispatch it carried is stale
        if self._crashed or not worker.alive or worker.epoch != epoch:
            return
        self._send_raw(worker, frame)

    # -- accounting transitions ----------------------------------------------

    def _release(self, worker: _LiveWorker, now: float) -> None:
        self._ws += now - worker.busy_since
        worker.assignment = None
        worker.scheduled_end = math.inf
        worker.lease_deadline = math.inf
        worker.progress = None

    def _cancel_replica(self, sib: _LiveWorker, now: float) -> None:
        job_id, batch = sib.assignment
        # the effective scheduled end is pushed at least one tick past 'now'
        # so reclaimed time stays positive and the replay's event for this
        # replica pops strictly after the winner's (where it is stale)
        sched_end = max(sib.scheduled_end, now + TICK)
        self._saved += sched_end - now
        self.recorder.record(
            "cancel", now, wid=sib.wid, job=job_id, batch=batch, sched_end=sched_end
        )
        self._send(sib, {"type": "cancel", "job": job_id, "batch": batch, "epoch": sib.epoch})
        sib.epoch += 1  # the in-flight finish (if any) is now stale
        self._release(sib, now)

    def _finish_job(self, jexec: _LiveExec, now: float) -> None:
        job = jexec.job
        self.records.append(
            JobRecord(
                job_id=job.job_id,
                name=job.name,
                # the recorded submit stamp, not the requested offset: this is
                # the arrival the engine replay sees, so records match exactly
                arrival=self._arrival_stamp[job.job_id],
                start=jexec.start,
                finish=now,
                n_batches=jexec.n_batches,
                replication=jexec.replication,
            )
        )
        self.completion_order.append(job.job_id)
        self.recorder.record(
            "job_done",
            now,
            job=job.job_id,
            start=jexec.start,
            n_batches=jexec.n_batches,
            replication=jexec.replication,
        )
        del self.active[job.job_id]
        self._drop_retry_state(job.job_id)
        if len(self.records) == self._n_jobs_expected:
            self._finalize(now)

    def _finalize(self, now: float) -> None:
        """End of run: charge still-in-flight replicas their full planned
        duration (the engine's flush rule) and freeze the trace -- nothing
        that happens on the sockets after this instant is part of the run."""
        for worker in self.workers:
            if worker.alive and worker.assignment is not None:
                job_id, batch = worker.assignment
                self._ws += worker.scheduled_end - worker.busy_since
                self.recorder.record(
                    "flush",
                    now,
                    wid=worker.wid,
                    job=job_id,
                    batch=batch,
                    sched_end=worker.scheduled_end,
                )
                self._send_raw(
                    worker,
                    {"type": "cancel", "job": job_id, "batch": batch, "epoch": worker.epoch},
                )
                worker.epoch += 1
                worker.assignment = None
                worker.scheduled_end = math.inf
        self._finalized = True
        self._pending_retries.clear()  # armed timers no-op via membership check
        self.recorder.frozen = True
        self._done.set()

    # -- crash recovery ------------------------------------------------------

    @classmethod
    def recover(
        cls,
        journal_path: str,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        heartbeat_s: float = 0.05,
        heartbeat_timeout_s: float = 0.5,
        lease_factor: float = 8.0,
        lease_floor_s: float = 2.0,
    ) -> "RuntimeMaster":
        """Rebuild a master from a write-ahead journal left by a crash.

        The journal's scenario header supplies the configuration; folding the
        remaining events re-derives queued and in-flight jobs, leases,
        attempts, armed backoffs, and every accounting counter.  Workers that
        were alive at the crash are stamped as failed with cause ``crash``
        (their sockets died with the old master), which routes their batches
        through the ordinary rescue path; a ``recover`` event marks the seam.
        The rebuilt master appends to the *same* journal, so the finished
        file replays crash + recovery through the DES twin as one exact
        trace.  Continue with ``start()``, re-spawn workers, ``resume()``.
        """
        events = read_journal(journal_path)
        if not events or events[0].get("ev") != "scenario":
            raise ValueError(f"{journal_path}: not a runtime journal (no scenario header)")
        head = events[0]
        return cls(
            int(head["n_workers"]),
            Scenario.from_dict(head["scenario"]),
            host=host,
            port=port,
            heartbeat_s=heartbeat_s,
            heartbeat_timeout_s=heartbeat_timeout_s,
            lease_factor=lease_factor,
            lease_floor_s=lease_floor_s,
            journal=journal_path,
            _resume_events=events,
        )

    def _rebuild(self, events: Sequence[dict]) -> None:
        """Replay the journaled decisions over this master's (empty) state --
        each branch mirrors the live handler that recorded the event, minus
        sockets and counters (the counters come from the trace fold, the
        sockets from workers re-joining after ``start()``)."""
        jobs: Dict[int, LiveJob] = {}
        chaos_events: List[dict] = []
        for e in events:
            kind, t = e["ev"], e.get("t", 0.0)
            if kind == "join":
                if e["wid"] == len(self.workers):
                    self.workers.append(
                        _LiveWorker(wid=int(e["wid"]), writer=None, pid=int(e.get("pid", -1)))
                    )
                else:  # re-join of a failed wid
                    w = self.workers[e["wid"]]
                    w.alive = True
                    w.assignment = None
                    w.scheduled_end = math.inf
                    w.progress = None
            elif kind == "fail":
                w = self.workers[e["wid"]]
                if w.assignment is not None:
                    job_id, batch = w.assignment
                    jexec = self.active.get(job_id)
                    if jexec is not None:
                        jexec.outstanding[batch].discard(w.wid)
                        if batch not in jexec.done and not jexec.outstanding[batch]:
                            self.rescue.append((job_id, batch))
                    w.assignment = None
                    w.scheduled_end = math.inf
                w.alive = False
                w.epoch += 1
            elif kind == "submit":
                job = LiveJob(
                    job_id=int(e["job"]),
                    costs=tuple(e["costs"]),
                    payload=e["payload"],
                    arrival=t,
                    name=e.get("name", ""),
                    plan=JobPlan(**e["plan"]) if e.get("plan") else None,
                    skew=float(e.get("skew", 0.0)),
                )
                jobs[job.job_id] = job
                self._arrival_stamp[job.job_id] = t
                self.queue.append(job)
            elif kind == "job_start":
                self.queue = [j for j in self.queue if j.job_id != e["job"]]
                self.active[e["job"]] = _LiveExec(
                    job=jobs[e["job"]],
                    start=t,
                    n_batches=int(e["n_batches"]),
                    replication=int(e["replication"]),
                    cancel=bool(e["cancel"]),
                )
            elif kind == "dispatch":
                w = self.workers[e["wid"]]
                w.assignment = (int(e["job"]), int(e["batch"]))
                w.busy_since = t
                w.scheduled_end = t + float(e["planned"])
                jexec = self.active[e["job"]]
                jexec.outstanding.setdefault(int(e["batch"]), set()).add(w.wid)
                if e.get("spec"):
                    jexec.spec_used += 1
                if e.get("retry"):
                    self._retry_batches.discard((int(e["job"]), int(e["batch"])))
                if e.get("rescue"):
                    # _assign_rescues consumes (and silently drops stale)
                    # entries from the head until it dispatches this one
                    while self.rescue:
                        if self.rescue.pop(0) == (int(e["job"]), int(e["batch"])):
                            break
            elif kind == "finish":
                w = self.workers[e["wid"]]
                since = w.busy_since
                w.assignment = None
                w.scheduled_end = math.inf
                jexec = self.active.get(e["job"])
                if jexec is not None:
                    batch = int(e["batch"])
                    jexec.outstanding[batch].discard(w.wid)
                    if batch not in jexec.done:
                        jexec.done.add(batch)
                        jexec.obs.append(t - since)
                        if jexec.cancel:
                            jexec.outstanding[batch].clear()
            elif kind == "cancel":
                w = self.workers[e["wid"]]
                w.epoch += 1
                w.assignment = None
                w.scheduled_end = math.inf
            elif kind == "task_fail":
                w = self.workers[e["wid"]]
                w.assignment = None
                w.scheduled_end = math.inf
                job_id, batch = int(e["job"]), int(e["batch"])
                self.task_errors.append((job_id, batch, w.wid, e.get("error", "")))
                jexec = self.active.get(job_id)
                if jexec is not None:
                    jexec.outstanding[batch].discard(w.wid)
                    if batch not in jexec.done:
                        attempt = self._attempts.get((job_id, batch), 0) + 1
                        self._attempts[(job_id, batch)] = attempt
                        retry = self.scenario.retry
                        if retry is not None and attempt <= retry.max_attempts:
                            self._retry_seq += 1
                            self._pending_retries.append(
                                (t + retry.backoff(attempt), self._retry_seq, job_id, batch,
                                 attempt)
                            )
            elif kind == "retry":
                job_id, batch = int(e["job"]), int(e["batch"])
                entry = min(p for p in self._pending_retries if p[2:4] == (job_id, batch))
                self._pending_retries.remove(entry)
                self._retry_batches.add((job_id, batch))
                self.rescue.append((job_id, batch))
            elif kind in ("job_done", "job_fail"):
                jexec = self.active.pop(e["job"])
                self.records.append(
                    JobRecord(
                        job_id=int(e["job"]),
                        name=jexec.job.name,
                        arrival=self._arrival_stamp[e["job"]],
                        start=float(e["start"]),
                        finish=t if kind == "job_done" else math.inf,
                        n_batches=int(e["n_batches"]),
                        replication=int(e["replication"]),
                    )
                )
                self.completion_order.append(int(e["job"]))
                self._drop_retry_state(int(e["job"]))
            elif kind == "flush":
                w = self.workers[e["wid"]]
                w.epoch += 1
                w.assignment = None
                w.scheduled_end = math.inf
            elif kind == "chaos":
                chaos_events.append(e)
        self._n_jobs_expected = sum(1 for e in events if e["ev"] == "submit")
        if self._chaos is not None:
            self._chaos.restore(chaos_events)
        acct = trace_accounting(events)
        self._ws = acct["worker_seconds"]
        self._saved = acct["cancelled_seconds_saved"]
        self._n_failures = acct["n_worker_failures"]
        self._n_rescued = acct["n_replicas_rescued"]
        self._n_spec = acct["n_speculative"]
        self._n_task_failures = acct["n_task_failures"]
        self._n_retries = acct["n_retries"]
        if len(self.records) >= self._n_jobs_expected:
            return  # the journaled run had already completed; nothing to heal
        # every worker alive at the crash lost its socket with the old
        # master: declare each failed (cause "crash") so in-flight batches
        # take the ordinary rescue path, then mark the seam
        for w in self.workers:
            if w.alive:
                self._fail(w, "crash")
        self.recorder.record(
            "recover",
            self.recorder.stamp(),
            n_active=len(self.active),
            n_queued=len(self.queue),
            n_pending_retries=len(self._pending_retries),
        )


class Runtime:
    """One-call facade: spawn workers, execute a workload, return the report.

    ``spawn="thread"`` runs each worker in-process on its own thread and
    event loop (cheap, deterministic teardown); ``spawn="subprocess"`` forks
    real ``python -m repro.cluster.runtime.worker`` processes, which chaos
    tests can SIGKILL mid-task.  Either way the master talks to them over
    real localhost sockets -- the protocol path is identical.
    """

    def __init__(
        self,
        n_workers: int,
        scenario: Optional[Scenario] = None,
        *,
        spawn: str = "thread",
        heartbeat_s: float = 0.05,
        heartbeat_timeout_s: float = 0.5,
        host: str = "127.0.0.1",
        journal: Optional[str] = None,
        n_batches=UNSET,
        cancel_redundant=UNSET,
        speculation=UNSET,
    ):
        if spawn not in ("thread", "subprocess"):
            raise ValueError(f"spawn must be 'thread' or 'subprocess', got {spawn!r}")
        self.n_workers = int(n_workers)
        self.scenario = resolve_scenario(
            scenario,
            {
                "n_batches": n_batches,
                "cancel_redundant": cancel_redundant,
                "speculation": speculation,
            },
            where="Runtime",
        )
        self.spawn = spawn
        self.heartbeat_s = heartbeat_s
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.host = host
        self.journal = journal

    def run(self, jobs: Sequence[LiveJob], timeout_s: float = 120.0) -> LiveReport:
        """Synchronous wrapper around :meth:`run_async`."""
        return asyncio.run(self.run_async(jobs, timeout_s=timeout_s))

    async def run_async(self, jobs: Sequence[LiveJob], timeout_s: float = 120.0) -> LiveReport:
        """Start a master, spawn/await the workers, run ``jobs``, tear down."""
        master = RuntimeMaster(
            self.n_workers,
            self.scenario,
            host=self.host,
            heartbeat_s=self.heartbeat_s,
            heartbeat_timeout_s=self.heartbeat_timeout_s,
            journal=self.journal,
        )
        port = await master.start()
        spawner = spawn_worker_thread if self.spawn == "thread" else spawn_worker_subprocess
        handles = [spawner(self.host, port) for _ in range(self.n_workers)]
        try:
            await master.wait_for_workers()
            report = await master.run(jobs, timeout_s=timeout_s)
        finally:
            await master.close()
            for h in handles:
                if hasattr(h, "join"):
                    h.join(timeout=5.0)
                else:
                    try:
                        h.wait(timeout=5.0)
                    except Exception:
                        h.kill()
        # sanity: the master's own counters must agree with the trace fold
        acct = trace_accounting(report.trace)
        if acct != report.accounting():  # pragma: no cover - internal invariant
            raise RuntimeError(f"trace fold disagrees with live counters: {acct}")
        return report
