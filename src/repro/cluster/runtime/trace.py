"""Trace recording and the digital-twin replay through the DES engine.

The runtime master stamps every state transition on a binary time grid of
``TICK = 2**-20`` seconds (~0.95 us).  Grid timestamps are exact binary
fractions, so every difference and sum the accounting takes -- elapsed busy
time, reclaimed replica time, scheduled ends -- is *exact* in float64, which
is what lets :func:`replay_trace` push the recorded schedule through
:class:`~repro.cluster.master.ClusterEngine` and demand bit-for-bit equality
with the live accounting rather than a tolerance.

Stamps are also strictly increasing across recorded events (ties bump to the
next grid point): the engine's event heap breaks time ties by insertion
order, so distinct stamps guarantee the replay pops events in exactly the
order the live master processed them.

Event vocabulary (``ev`` field):

=========  =============================================================
scenario   first event: the originating Scenario (t, n_workers,
           scenario = ``Scenario.to_dict()``) -- a trace file alone is
           replayable
join       worker registered (t, wid)
submit     job entered the queue (t, job, n_tasks, plan, costs, payload,
           skew -- enough to resume the job from a journal)
job_start  job activated on the cluster (t, job, n_batches, replication,
           cancel) -- stamped just before its gang's dispatches
dispatch   replica placed on a worker (t, wid, job, batch, planned,
           rescue, spec, retry -- ``spec=True`` marks a speculative
           backup, ``retry=True`` a re-dispatch after a payload failure)
finish     replica's finish processed (t, wid, job, batch)
cancel     outstanding sibling reclaimed (t, wid, job, batch, sched_end)
fail       worker declared dead (t, wid, cause:
           eof|heartbeat|lease|crash -- ``crash`` marks workers lost
           with the master, stamped by ``RuntimeMaster.recover``)
task_fail  replica's payload raised (t, wid, job, batch, attempt, error)
retry      a failed replica's backoff expired; it re-enters the rescue
           queue (t, job, batch, attempt)
job_fail   job abandoned -- retry budget exhausted with nothing in
           flight (t, job, start, n_batches, replication)
flush      replica still in flight at run end (t, wid, job, batch, sched_end)
job_done   job completed (t, job, start, n_batches, replication)
chaos      informational: a fault the injector delivered (t, kind, ...);
           replay ignores it, recovery uses it to restore which faults
           already fired
recover    master rebuilt from the journal (t, n_active, n_queued)
=========  =============================================================

``replay_trace`` rebuilds the identical workload -- jobs at their recorded
arrival stamps, worker failures as an explicit
:class:`~repro.cluster.workers.ChurnSchedule` at their detection stamps, and
every replica duration scripted from the trace (elapsed time for finished
replicas; the recorded scheduled end for cancelled/failed/flushed ones) --
and runs the event engine on it.  The engine re-*derives* every decision
(gang dispatch order, rescue targets, sibling cancellation), so agreement is
a real differential check of the two implementations, not a tautology.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Dict, List, Optional, Tuple

__all__ = [
    "TICK",
    "TraceRecorder",
    "read_journal",
    "replay_trace",
    "trace_accounting",
]

_GRID = 1 << 20
TICK = 1.0 / _GRID  # the master's time quantum: one grid unit, ~0.95 us


def quantize(seconds: float) -> float:
    """Round a duration up onto the grid (durations stay strictly positive)."""
    return max(1, math.ceil(seconds * _GRID)) / _GRID


class TraceRecorder:
    """Event log + the master's monotone, grid-quantized clock.

    ``stamp()`` reads the process monotonic clock relative to the recorder's
    birth, quantizes it to the grid, and enforces strict increase -- two
    events can never share a timestamp, so replay order is total.

    ``journal`` names an append-only JSONL write-ahead log: every recorded
    event is written and ``fsync``'d *at the decision point*, before the
    decision's effects go on the wire, so a master crash never loses an
    acknowledged state transition.  ``resume_events`` (recovery) seeds the
    recorder with a previously journaled prefix: the clock continues from
    the last journaled stamp (strict increase holds across the crash) and
    the journal file is appended to, not truncated -- after recovery the one
    file holds the crash *and* the recovery as a single replayable trace.
    """

    def __init__(self, journal: Optional[str] = None, resume_events=None):
        self._events: List[dict] = list(resume_events) if resume_events else []
        last = self._events[-1]["t"] if self._events else 0.0
        self._last_g = int(round(last * _GRID))
        self._t0 = time.monotonic() - last
        self.frozen = False
        self.journal_path = journal
        self._journal = None
        if journal is not None:
            self._journal = open(journal, "ab" if resume_events else "wb")

    def elapsed(self) -> float:
        """Raw (unquantized) seconds since the recorder was born."""
        return time.monotonic() - self._t0

    def stamp(self) -> float:
        """Quantized, strictly increasing timestamp for the next event."""
        g = int(self.elapsed() * _GRID)
        self._last_g = max(g, self._last_g + 1)
        return self._last_g / _GRID

    def record(self, ev: str, t: float, **fields) -> None:
        """Append one event, write-ahead journaling it when enabled."""
        if self.frozen:
            raise RuntimeError("trace is frozen; the run already finalized")
        event = {"ev": ev, "t": t, **fields}
        self._events.append(event)
        if self._journal is not None:
            self._journal.write(json.dumps(event).encode("utf-8") + b"\n")
            self._journal.flush()
            os.fsync(self._journal.fileno())

    def close_journal(self) -> None:
        """Close the write-ahead journal file, if one is open."""
        if self._journal is not None:
            self._journal.close()
            self._journal = None

    @property
    def events(self) -> Tuple[dict, ...]:
        """Everything recorded so far, in stamp order."""
        return tuple(self._events)


def read_journal(path: str) -> List[dict]:
    """Load a JSONL trace journal, tolerating a torn final line.

    A crash can interrupt the write of the last record; the fsync discipline
    guarantees every *complete* line was a decision whose effects may have
    reached the wire, so those are kept and a trailing partial line (no
    terminating newline / invalid JSON) is discarded.
    """
    events: List[dict] = []
    with open(path, "rb") as f:
        data = f.read()
    for i, line in enumerate(data.split(b"\n")):
        if not line:
            continue
        try:
            events.append(json.loads(line))
        except json.JSONDecodeError:
            if i == data.count(b"\n"):  # torn final line (crash mid-write)
                break
            raise
    return events


# --------------------------------------------------------------------------
# accounting fold: the runtime's counters derived purely from the trace
# --------------------------------------------------------------------------


def trace_accounting(events) -> dict:
    """Fold a trace into the engine's invariant-bearing counters.

    Returns the same key set as
    :meth:`~repro.cluster.master.EngineReport.accounting` (the live runtime
    has no online replanner, so ``n_replans`` is 0).  This is a *pure*
    function of the event log -- the differential tests check it against
    both the live master's own counters and the engine replay's.
    """
    ws = 0.0
    saved = 0.0
    n_failures = 0
    n_rescued = 0
    n_spec = 0
    n_task_failures = 0
    n_retries = 0
    busy: Dict[int, dict] = {}  # wid -> its open dispatch event
    for e in events:
        kind = e["ev"]
        if kind == "dispatch":
            busy[e["wid"]] = e
            if e.get("retry"):
                n_retries += 1
            elif e["rescue"]:
                n_rescued += 1
            if e.get("spec"):
                n_spec += 1
        elif kind == "finish":
            d = busy.pop(e["wid"])
            ws += e["t"] - d["t"]
        elif kind == "cancel":
            d = busy.pop(e["wid"])
            ws += e["t"] - d["t"]
            saved += e["sched_end"] - e["t"]
        elif kind == "fail":
            n_failures += 1
            d = busy.pop(e["wid"], None)
            if d is not None:
                ws += e["t"] - d["t"]
        elif kind == "task_fail":
            n_task_failures += 1
            d = busy.pop(e["wid"])
            ws += e["t"] - d["t"]
        elif kind == "flush":
            d = busy.pop(e["wid"])
            ws += e["sched_end"] - d["t"]
    return {
        "worker_seconds": ws,
        "cancelled_seconds_saved": saved,
        "n_worker_failures": n_failures,
        "n_replicas_rescued": n_rescued,
        "n_replans": 0,
        "n_speculative": n_spec,
        "n_task_failures": n_task_failures,
        "n_retries": n_retries,
    }


# --------------------------------------------------------------------------
# the digital twin: replay the recorded schedule through ClusterEngine
# --------------------------------------------------------------------------


@dataclasses.dataclass
class _ScriptedService:
    """A ServiceTime stand-in that pops recorded replica durations in order.

    The engine draws exactly one service time per replica it dispatches, in
    dispatch order; with ``size_dependent=False`` and homogeneous unit
    speeds the draw *is* the wall-clock duration.  Exhausting the script --
    or leaving part of it unconsumed -- means the engine made a different
    dispatch sequence than the live master: a genuine divergence, reported
    loudly instead of silently misaligning durations.
    """

    durations: Tuple[float, ...]
    cursor: int = 0

    def sample_np(self, rng, shape):
        if shape not in ((), None):  # pragma: no cover - engine always draws scalars
            raise ValueError(f"scripted service draws scalars, got shape {shape}")
        if self.cursor >= len(self.durations):
            raise RuntimeError(
                "trace replay diverged: the engine dispatched more replicas "
                f"than the trace recorded ({len(self.durations)})"
            )
        d = self.durations[self.cursor]
        self.cursor += 1
        return d


def _scripted_durations(events) -> Tuple[Tuple[float, ...], Tuple[int, ...]]:
    """Per-dispatch scripted durations (in dispatch order) + which global
    dispatch indices failed their payload.

    finished   -> elapsed (finish stamp - dispatch stamp): the engine's
                  BATCH_DONE then lands exactly on the recorded finish stamp;
    cancelled  -> recorded effective scheduled end - dispatch stamp: the
                  engine's ``scheduled_end`` (and so its saved-seconds)
                  matches the live accounting, and the event pops strictly
                  after the winner's, where the epoch guard drops it;
    task_fail  -> elapsed at the recorded failure stamp: the engine's
                  TASK_FAIL event lands exactly there, charging the same
                  busy time the live master did;
    failed     -> pushed past the failure stamp so the fail event wins the
                  race (worker-seconds charge only reads ``busy_since``);
    flushed    -> the recorded scheduled end (full planned duration), the
                  engine's end-of-run committed-time charge.
    """
    durations: List[float] = []
    fail_idx: List[int] = []
    slot: Dict[int, int] = {}  # wid -> index into durations of its open dispatch
    start: Dict[int, float] = {}
    for e in events:
        kind = e["ev"]
        if kind == "dispatch":
            slot[e["wid"]] = len(durations)
            start[e["wid"]] = e["t"]
            durations.append(e["planned"])  # placeholder until the outcome is known
        elif kind == "finish":
            durations[slot.pop(e["wid"])] = e["t"] - start.pop(e["wid"])
        elif kind in ("cancel", "flush"):
            durations[slot.pop(e["wid"])] = e["sched_end"] - start.pop(e["wid"])
        elif kind == "task_fail":
            k = slot.pop(e["wid"])
            fail_idx.append(k)
            durations[k] = e["t"] - start.pop(e["wid"])
        elif kind == "fail":
            k = slot.pop(e["wid"], None)
            if k is not None:
                t0 = start.pop(e["wid"])
                durations[k] = max(durations[k], e["t"] - t0 + TICK)
    if slot:  # pragma: no cover - the master always closes open dispatches
        raise RuntimeError(f"trace ended with open dispatches on workers {sorted(slot)}")
    return tuple(durations), tuple(fail_idx)


def replay_trace(events, n_workers: Optional[int] = None, scenario=None):
    """Replay a recorded runtime trace through the discrete-event engine.

    Builds the identical workload the live master saw -- same arrival
    stamps, same worker-failure timeline, same per-replica durations -- and
    returns the engine's :class:`~repro.cluster.master.EngineReport`.  The
    engine independently re-derives dispatch, rescue, and cancellation
    decisions; if runtime and engine implement the same semantics, the
    report's accounting and job records equal the live ones bit for bit.

    ``scenario`` / ``n_workers`` default to the trace's embedded
    ``scenario`` event (the master records its originating
    :class:`~repro.cluster.scenario.Scenario` and worker budget as the
    first event), so ``replay_trace(events)`` works on a bare trace file;
    per-job :class:`~repro.cluster.scheduler.JobPlan` overrides ride in the
    trace's ``submit`` events.

    Speculative launches replay *scripted*: each live launch stamp becomes
    a ``speculation_times`` epoch, and the engine re-derives the target
    batch and worker under the same policy -- a divergence raises instead
    of silently misaligning the schedule.  Task failures replay the same
    way: each ``task_fail`` event marks its global dispatch index as a
    scripted payload failure, each ``retry`` stamp re-queues the pending
    replica, and the engine re-derives attempts, backoff bookkeeping, and
    abandonment under the same :class:`~repro.cluster.scenario.Retry`
    policy.  ``chaos`` / ``recover`` events are informational: the faults'
    *consequences* (churn, task failures, the crash's worker losses) are
    already first-class events, so a chaos-and-crash run replays through
    the same engine path as a clean one.
    """
    from ..master import ClusterEngine, Job
    from ..scenario import Scenario
    from ..scheduler import JobPlan
    from ..workers import ChurnSchedule

    embedded = next((e for e in events if e["ev"] == "scenario"), None)
    sc = scenario
    if sc is None and embedded is not None:
        sc = Scenario.from_dict(embedded["scenario"])
    if sc is None:
        sc = Scenario()
    if n_workers is None:
        if embedded is None:
            raise ValueError(
                "replay_trace: n_workers is required when the trace has no "
                "embedded scenario event"
            )
        n_workers = int(embedded["n_workers"])
    durations, task_fail_idx = _scripted_durations(events)
    dist = _ScriptedService(durations)

    jobs = []
    churn_times: List[float] = []
    churn_wids: List[int] = []
    churn_ups: List[bool] = []
    down: set = set()
    for e in events:
        if e["ev"] == "submit":
            plan = e.get("plan")
            jobs.append(
                Job(
                    job_id=e["job"],
                    dist=dist,
                    n_tasks=e["n_tasks"],
                    arrival=e["t"],
                    name=e.get("name", ""),
                    plan=JobPlan(**plan) if plan else None,
                )
            )
        elif e["ev"] == "fail":
            churn_times.append(e["t"])
            churn_wids.append(e["wid"])
            churn_ups.append(False)
            down.add(e["wid"])
        elif e["ev"] == "join" and e["wid"] in down:
            # a re-join: the master retired the wid's stale registration and
            # granted it to a fresh connection -- an up-transition on the
            # engine's shared churn timeline (first-time joins at startup
            # precede any fail and stay outside the schedule)
            churn_times.append(e["t"])
            churn_wids.append(e["wid"])
            churn_ups.append(True)
            down.discard(e["wid"])

    schedule = None
    if churn_times:
        schedule = ChurnSchedule(
            times=tuple(churn_times),
            wids=tuple(churn_wids),
            ups=tuple(churn_ups),
        )
    spec_times = tuple(
        e["t"] for e in events if e["ev"] == "dispatch" and e.get("spec")
    )
    if spec_times and sc.speculation is None:
        raise ValueError(
            "replay_trace: the trace stamps speculative launches but the "
            "scenario carries no Speculation policy"
        )
    retry_times = tuple(e["t"] for e in events if e["ev"] == "retry")
    if retry_times and sc.retry is None:
        raise ValueError(
            "replay_trace: the trace stamps retries but the scenario "
            "carries no Retry policy"
        )
    engine = ClusterEngine(
        n_workers,
        seed=0,  # the scripted service ignores the rng; nothing else draws
        n_batches=sc.n_batches,
        cancel_redundant=sc.cancel_redundant,
        size_dependent=False,  # scripted draws are wall-clock durations
        churn_schedule=schedule,
        speculation=sc.speculation,
        # scripted replay: launch exactly at the live stamps, never self-arm
        speculation_times=spec_times if sc.speculation is not None else None,
        retry=sc.retry,
        task_fail_script=task_fail_idx or None,
        retry_times=retry_times if sc.retry is not None else None,
    )
    report = engine.run(jobs)
    if dist.cursor != len(dist.durations):
        raise RuntimeError(
            "trace replay diverged: the engine dispatched "
            f"{dist.cursor} replicas, the trace recorded {len(dist.durations)}"
        )
    return report
