"""Deterministic fault injection for the live runtime.

One :class:`FaultInjector` per master, configured by a serializable
:class:`~repro.cluster.scenario.FaultPlan` on the Scenario.  Every fault
decision is made *master-side* -- kills tear the worker's connection,
slowdowns and payload errors ride as flags in the task frame, heartbeat
stalls drop inbound ``hb`` frames, wire faults act on the master's
send/receive boundary -- so each delivered fault can be stamped on the
binary trace grid as an informational ``chaos`` event.  That buys two
properties the chaos tests lean on:

* **replayability** -- the faulted run's trace replays through the DES
  engine bit-exactly, because every consequence of a fault (a torn
  connection, a payload exception, a blown lease) is an ordinary
  first-class trace event;
* **crash-safety** -- the delivered-fault state is rebuilt from the
  journaled ``chaos`` events on :meth:`RuntimeMaster.recover`, so a
  scheduled kill fires at most once per run even across a master crash.

Wire-fault decisions are a pure function of ``(seed, direction, frame
index)`` via a crc32 hash -- no RNG state to persist, and independent of
Python's per-process hash salt.
"""

from __future__ import annotations

import zlib
from typing import Dict, Iterable, List, Set, Tuple

from ..scenario import FaultPlan

__all__ = ["FaultInjector", "WIRE_PASS", "WIRE_DROP", "WIRE_DUP", "WIRE_DELAY"]

WIRE_PASS = "pass"
WIRE_DROP = "drop"
WIRE_DUP = "dup"
WIRE_DELAY = "delay"


def _uniform(seed: int, direction: str, k: int) -> float:
    """Deterministic U[0,1) for the k-th frame in a direction."""
    h = zlib.crc32(f"{seed}:{direction}:{k}".encode("ascii"))
    return h / 4294967296.0


class FaultInjector:
    """Tracks which faults of a :class:`FaultPlan` have been delivered."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._killed: Set[int] = set()  # wids whose scheduled kill fired
        self._raises: Dict[Tuple[int, int], int] = {}  # (job, batch) -> raises delivered
        self._stalls_stamped: Set[int] = set()  # hb_stall entries already stamped
        self._counts = {"in": 0, "out": 0}

    # -- wire faults ---------------------------------------------------------

    def wire(self, direction: str) -> str:
        """Fate of the next frame in ``direction`` ('in' master<-worker,
        'out' master->worker): pass | drop | dup | delay.
        """
        k = self._counts[direction]
        self._counts[direction] = k + 1
        p = self.plan
        if p.drop_p == 0.0 and p.dup_p == 0.0 and p.delay_p == 0.0:
            return WIRE_PASS
        u = _uniform(p.seed, direction, k)
        if u < p.drop_p:
            return WIRE_DROP
        if u < p.drop_p + p.dup_p:
            return WIRE_DUP
        if u < p.drop_p + p.dup_p + p.delay_p:
            return WIRE_DELAY
        return WIRE_PASS

    # -- scheduled faults ----------------------------------------------------

    def due_kills(self, elapsed: float) -> List[int]:
        """Wids whose scheduled kill time has passed and not yet fired.
        Callers mark delivery with :meth:`mark_killed`.
        """
        return [
            int(wid)
            for wid, at in self.plan.kills
            if at <= elapsed and int(wid) not in self._killed
        ]

    def mark_killed(self, wid: int) -> None:
        """Note that the scheduled kill for ``wid`` has been delivered."""
        self._killed.add(int(wid))

    def slow_factor(self, wid: int, elapsed: float) -> float:
        """Compound slowdown factor for tasks dispatched to ``wid`` now."""
        f = 1.0
        for w, at, factor in self.plan.slowdowns:
            if int(w) == int(wid) and at <= elapsed:
                f *= float(factor)
        return f

    def stalled_window(self, wid: int, elapsed: float) -> "int | None":
        """Index of the hb_stall entry covering ``wid`` now, else None."""
        for i, (w, at, dur) in enumerate(self.plan.hb_stalls):
            if int(w) == int(wid) and at <= elapsed < at + dur:
                return i
        return None

    def stall_needs_stamp(self, window: int) -> bool:
        """Stamp each stall window once (at first dropped heartbeat), not per
        frame -- the journal records the fault, not every suppressed hb.
        """
        if window in self._stalls_stamped:
            return False
        self._stalls_stamped.add(window)
        return True

    def payload_raise(self, job: int, batch: int) -> bool:
        """Whether this dispatch of (job, batch) should raise mid-payload.
        Counts deliveries, so the first ``n_raises`` dispatches fail and
        later ones run clean.
        """
        for j, b, n in self.plan.payload_errors:
            if int(j) == int(job) and int(b) == int(batch):
                done = self._raises.get((job, batch), 0)
                if done < int(n):
                    self._raises[(job, batch)] = done + 1
                    return True
        return False

    # -- crash recovery ------------------------------------------------------

    def restore(self, chaos_events: Iterable[dict]) -> None:
        """Rebuild delivered-fault state from journaled ``chaos`` events so a
        recovered master does not re-deliver scheduled faults.
        """
        for e in chaos_events:
            kind = e.get("kind")
            if kind == "kill":
                self._killed.add(int(e["wid"]))
            elif kind == "raise":
                key = (int(e["job"]), int(e["batch"]))
                self._raises[key] = self._raises.get(key, 0) + 1
            elif kind == "hb_stall":
                self._stalls_stamped.add(int(e["window"]))
