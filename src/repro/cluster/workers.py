"""Worker-side state: per-worker service draws, speeds, and churn processes.

A worker executes one batch replica at a time.  Its service time for a batch
of ``s`` tasks is ``s * tau / speed`` under the paper's §VI size-dependent
model (``tau / speed`` under the §IV batch-level model), with ``tau`` drawn
from the job's :class:`~repro.core.service_time.ServiceTime` distribution.
Heterogeneous clusters set per-worker ``speed`` factors; time-varying
stragglers are modeled by the fail/join churn process (a straggling worker is
a worker that leaves and later rejoins).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import numpy as np

from ..core.service_time import ServiceTime

__all__ = [
    "Worker",
    "WorkerPool",
    "ChurnProcess",
    "ChurnSchedule",
    "sample_churn_schedule",
    "draw_batch_time",
]


@dataclasses.dataclass
class Worker:
    """Mutable execution state for one worker."""

    wid: int
    speed: float = 1.0
    alive: bool = True
    # (job_id, batch) currently executing; None when idle
    assignment: Optional[Tuple[int, int]] = None
    # epoch is bumped on cancellation/failure; in-flight BATCH_DONE events
    # carry the epoch they were scheduled under and are dropped on mismatch
    epoch: int = 0
    # churn_epoch tracks alive/dead transitions only -- WORKER_FAIL/JOIN
    # events check it, so cancelling a replica (which bumps ``epoch``) does
    # not invalidate the worker's pending failure event
    churn_epoch: int = 0
    busy_since: float = 0.0
    scheduled_end: float = math.inf

    @property
    def free(self) -> bool:
        """Alive and not currently assigned a replica."""
        return self.alive and self.assignment is None


class WorkerPool:
    """The cluster's worker set (possibly heterogeneous speeds)."""

    def __init__(self, n_workers: int, speeds: Optional[Sequence[float]] = None):
        if speeds is None:
            speeds = [1.0] * n_workers
        if len(speeds) != n_workers:
            raise ValueError("speeds must have one entry per worker")
        self.workers = [Worker(wid=i, speed=float(s)) for i, s in enumerate(speeds)]

    def __getitem__(self, wid: int) -> Worker:
        return self.workers[wid]

    def __iter__(self):
        return iter(self.workers)

    def __len__(self) -> int:
        return len(self.workers)

    def free_workers(self) -> list:
        """Workers currently free, in wid order."""
        return [w for w in self.workers if w.free]

    def n_alive(self) -> int:
        """How many workers are currently alive."""
        return sum(1 for w in self.workers if w.alive)


@dataclasses.dataclass(frozen=True)
class ChurnProcess:
    """Fail/join dynamics: exponential failure hazard + exponential downtime.

    ``fail_rate`` is the per-alive-worker failure rate; ``mean_downtime`` is
    the mean time a failed worker stays away before rejoining (0 disables
    rejoin: failures are permanent departures).
    """

    fail_rate: float = 0.0
    mean_downtime: float = 0.0

    def next_failure(self, rng: np.random.Generator) -> float:
        """Draw the time until this worker's next failure."""
        if self.fail_rate <= 0.0:
            return math.inf
        return float(rng.exponential(1.0 / self.fail_rate))

    def downtime(self, rng: np.random.Generator) -> float:
        """Draw how long a failed worker stays away before rejoining."""
        if self.mean_downtime <= 0.0:
            return math.inf
        return float(rng.exponential(self.mean_downtime))


@dataclasses.dataclass(frozen=True)
class ChurnSchedule:
    """An explicit, replayable fail/join timeline (the cluster's churn *epochs*).

    Where :class:`ChurnProcess` describes churn as a stochastic law that the
    engine samples while it runs, a schedule pins the realization: event k
    flips worker ``wids[k]`` down (``ups[k]`` False) or up (True) at
    ``times[k]``.  Both backends replay the same schedule -- the event engine
    pushes the events onto its heap, the jax epoch-scan ``lax.scan``s over
    them -- which is what lets the differential test harness compare churned
    runs across backends on a shared timeline.

    Per worker the events must alternate fail/join starting from alive, and
    ``times`` must be globally sorted (ties allowed).
    """

    times: tuple
    wids: tuple
    ups: tuple

    def __post_init__(self):
        if not (len(self.times) == len(self.wids) == len(self.ups)):
            raise ValueError("times/wids/ups must have equal length")
        if any(t2 < t1 for t1, t2 in zip(self.times, self.times[1:])):
            raise ValueError("schedule times must be sorted")
        state: dict = {}
        for t, w, up in zip(self.times, self.wids, self.ups):
            if t < 0 or not math.isfinite(t):
                raise ValueError(f"event times must be finite and >= 0, got {t}")
            if bool(up) == state.get(w, True):
                raise ValueError(f"worker {w}: fail/join events must alternate from alive")
            state[w] = bool(up)

    def __len__(self) -> int:
        return len(self.times)


def sample_churn_schedule(
    churn: ChurnProcess,
    n_workers: int,
    rng: np.random.Generator,
    pairs_per_worker: int = 8,
) -> ChurnSchedule:
    """One realization of ``churn``: the alternating-renewal timeline per worker.

    Each worker alternates up ~ Exp(fail_rate) and down ~ Exp(mean_downtime)
    intervals, exactly the law :class:`~repro.cluster.master.ClusterEngine`
    samples online; after ``pairs_per_worker`` fail/join pairs the worker
    stays up (the truncation both backends then share).  Zero ``fail_rate``
    yields an empty schedule; zero ``mean_downtime`` makes failures permanent
    (the join of each pair lands at infinity and is dropped).
    """
    events: list = []
    for w in range(n_workers):
        t = 0.0
        for _ in range(pairs_per_worker):
            up = churn.next_failure(rng)
            if not math.isfinite(up):
                break
            t += up
            events.append((t, w, False))
            down = churn.downtime(rng)
            if not math.isfinite(down):
                break
            t += down
            events.append((t, w, True))
    events.sort()
    return ChurnSchedule(
        times=tuple(e[0] for e in events),
        wids=tuple(e[1] for e in events),
        ups=tuple(e[2] for e in events),
    )


def draw_batch_time(
    dist: ServiceTime,
    rng: np.random.Generator,
    batch_tasks: float,
    speed: float,
    size_dependent: bool,
) -> float:
    """One replica's wall-clock time for a batch of ``batch_tasks`` tasks."""
    tau = float(np.asarray(dist.sample_np(rng, ())))
    work = tau * batch_tasks if size_dependent else tau
    return work / speed
