"""§Technique: the paper's replication applied to the training step itself.

The RDP r=2 dry-run cell (mesh (replica=2, shard=8, model=16)) measures the
lockstep COST of diversity: per-device FLOPs double vs the (16,16) baseline.
This benchmark quantifies the BENEFIT side with the paper's own model: for a
multi-controller deployment where each of N=16 data-parallel worker groups
has a random per-step service time, the step completes at

    baseline (B=16): T = max over 16 groups          (any straggler stalls)
    RDP r=2  (B=8):  T = max over 8 shards of min over 2 replicas

i.e. exactly the paper's T = max_B min_r with the step as the job.
"""
from __future__ import annotations

import time

import jax

from repro.core import simulator
from repro.core.service_time import Exponential, Pareto, ShiftedExponential

N = 16  # data-parallel worker groups (the production data axis)


def bench_rdp_step_time(n_mc: int = 200_000):
    t0 = time.time()
    rows = []
    for dist, label in [
        (ShiftedExponential(delta=1.0, mu=10.0), "mild variance (SExp, d*mu=10)"),
        (ShiftedExponential(delta=0.2, mu=1.0), "high variance (SExp, d*mu=0.2)"),
        (Pareto(sigma=1.0, alpha=1.5), "heavy tail (Pareto a=1.5)"),
        (Exponential(mu=1.0), "memoryless (Exp)"),
    ]:
        base = simulator.simulate_balanced(
            jax.random.key(0), dist, N, N, n_mc, size_dependent=False
        )
        rdp = simulator.simulate_balanced(
            jax.random.key(1), dist, N, N // 2, n_mc, size_dependent=False
        )
        sb, sr = simulator.stats_from_samples(base), simulator.stats_from_samples(rdp)
        # lockstep compute cost of r=2 is 2x; replication wins end-to-end when
        # the straggler speedup exceeds it
        speedup = sb.mean / sr.mean
        rows.append((label, speedup, sb, sr))
    us = (time.time() - t0) * 1e6 / 8
    out = []
    for label, speedup, sb, sr in rows:
        out.append((
            f"technique_rdp_{label.split()[0]}",
            us,
            f"E[T] {sb.mean:.2f}->{sr.mean:.2f} ({speedup:.2f}x), "
            f"p99 {sb.p99:.2f}->{sr.p99:.2f}; wins lockstep iff >2.0x",
        ))
    return out


def run_all():
    return bench_rdp_step_time()
