"""Benchmark aggregator: one function per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV.  The 512-device dry-run itself is a
separate (long-running) launcher: ``python -m repro.launch.dryrun``; here we
consume its artifacts for the roofline rows if present.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))


def main() -> None:
    from benchmarks import (
        cluster_bench,
        kernel_bench,
        paper_figs,
        roofline,
        technique_bench,
        traces_bench,
    )

    rows = []
    rows.extend(paper_figs.run_all())
    rows.extend(traces_bench.run_all())
    rows.extend(cluster_bench.run_all(smoke=True))
    rows.extend(kernel_bench.run_all())
    rows.extend(technique_bench.run_all())
    try:
        rows.extend(roofline.run_all())
    except Exception as e:  # artifacts absent: dry-run not yet executed
        rows.append(("roofline", 0.0, f"skipped: {type(e).__name__}: {e}"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
