"""Paper-figure benchmarks: closed forms vs Monte-Carlo for Figs. 3, 6-10.

Each function reproduces one figure's data and returns rows
(name, us_per_call, derived) where `derived` summarizes the figure's claim.
Artifacts (full curves) are written to benchmarks/artifacts/paper/.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from repro.core import analysis, batching, coupon, simulator
from repro.core.service_time import Exponential

ART = pathlib.Path(__file__).resolve().parent / "artifacts" / "paper"


def _save(name: str, payload: dict) -> None:
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{name}.json").write_text(json.dumps(payload, indent=2))


def bench_fig3_coverage():
    """Lemma 1 / Fig 3: P(cover B batches with N workers), N in {10,50,100,500}."""
    t0 = time.time()
    curves = {}
    for n in (10, 50, 100, 500):
        bs = [b for b in range(1, n + 1) if n % b == 0 or b <= 60]
        curves[str(n)] = {
            "B": bs,
            "p_cover": [coupon.coverage_probability(n, b) for b in bs],
        }
    # the paper's headline: N=100 covers only ~B<=10 batches w.h.p.
    p10 = coupon.coverage_probability(100, 10)
    p25 = coupon.coverage_probability(100, 25)
    _save("fig3_coverage", curves)
    us = (time.time() - t0) * 1e6 / sum(len(c["B"]) for c in curves.values())
    return [("fig3_coverage", us, f"P(100,10)={p10:.3f};P(100,25)={p25:.3f}")]


def bench_fig6_scheme_ordering(n_samples: int = 120_000):
    """§V / Fig 6: E[T] cyclic(1) > hybrid(2) > non-overlapping(3)."""
    t0 = time.time()
    n, b = 6, 3
    dist = Exponential(mu=1.0)
    out = {}
    for name, m in (
        ("scheme1_cyclic", batching.cyclic(n, b)),
        ("scheme2_hybrid", batching.hybrid(n, b)),
        ("scheme3_nonoverlap", batching.non_overlapping(n, b)),
    ):
        tarr = simulator.simulate_membership(jax.random.key(0), dist, m, n_samples)
        out[name] = simulator.stats_from_samples(tarr).mean
    _save("fig6_schemes", out)
    us = (time.time() - t0) * 1e6 / 3
    ordered = out["scheme3_nonoverlap"] < out["scheme2_hybrid"] < out["scheme1_cyclic"]
    return [(
        "fig6_schemes", us,
        f"E3={out['scheme3_nonoverlap']:.3f}<E2={out['scheme2_hybrid']:.3f}"
        f"<E1={out['scheme1_cyclic']:.3f}:{'ok' if ordered else 'VIOLATED'}",
    )]


def bench_fig7_sexp_mean():
    """Thm 5 / Fig 7: E[T] vs B for SExp(0.05, mu), N=100."""
    t0 = time.time()
    n, delta = 100, 0.05
    curves = {}
    argmins = {}
    for mu in (0.1, 1.0, 5.0, 20.0):
        bs = analysis.feasible_B(n)
        ys = [analysis.sexp_mean_T(n, b, delta, mu) for b in bs]
        curves[str(mu)] = {"B": bs, "ET": ys}
        argmins[str(mu)] = int(bs[int(np.argmin(ys))])
    _save("fig7_sexp_mean", curves)
    us = (time.time() - t0) * 1e6 / (4 * len(analysis.feasible_B(n)))
    return [("fig7_sexp_mean", us, f"B*={argmins} (diversity->parallelism as mu grows)")]


def bench_fig8_sexp_cov():
    """Thm 7 / Fig 8: CoV vs B for SExp(0.05, mu), N=100."""
    t0 = time.time()
    n, delta = 100, 0.05
    curves, argmins = {}, {}
    for mu in (0.2, 0.8, 5.0, 20.0):
        bs = analysis.feasible_B(n)
        ys = [analysis.sexp_cov_T(n, b, delta, mu) for b in bs]
        curves[str(mu)] = {"B": bs, "CoV": ys}
        argmins[str(mu)] = int(bs[int(np.argmin(ys))])
    _save("fig8_sexp_cov", curves)
    us = (time.time() - t0) * 1e6 / (4 * len(analysis.feasible_B(n)))
    return [("fig8_sexp_cov", us, f"CoV B*={argmins} (ends of spectrum; Cor 3 corrected)")]


def bench_fig9_pareto_mean():
    """Thm 8-9 / Fig 9: E[T] vs B for Pareto(1, alpha), N=100."""
    t0 = time.time()
    n = 100
    curves, argmins = {}, {}
    for alpha in (1.2, 2.0, 3.0, 5.0, 8.0):
        bs = analysis.feasible_B(n)
        ys = [analysis.pareto_mean_T(n, b, 1.0, alpha) for b in bs]
        curves[str(alpha)] = {"B": bs, "ET": ys}
        argmins[str(alpha)] = int(bs[int(np.argmin(ys))])
    a_star = analysis.pareto_alpha_star(n)
    _save("fig9_pareto_mean", curves)
    us = (time.time() - t0) * 1e6 / (5 * len(analysis.feasible_B(n)))
    return [("fig9_pareto_mean", us, f"B*={argmins}; alpha*~{a_star:.2f} (paper: ~4.7)")]


def bench_fig10_pareto_cov():
    """Thm 10 / Fig 10: CoV vs B minimized at full diversity for all alpha>2."""
    t0 = time.time()
    n = 100
    curves, argmins = {}, {}
    for alpha in (2.5, 3.0, 5.0, 10.0):
        bs = analysis.feasible_B(n)
        ys = [analysis.pareto_cov_T(n, b, alpha) for b in bs]
        curves[str(alpha)] = {"B": bs, "CoV": ys}
        argmins[str(alpha)] = int(bs[int(np.argmin(ys))])
    _save("fig10_pareto_cov", curves)
    us = (time.time() - t0) * 1e6 / (4 * len(analysis.feasible_B(n)))
    all_dev = all(v == 1 for v in argmins.values())
    verdict = "full diversity (Thm 10 ok)" if all_dev else "VIOLATED"
    return [("fig10_pareto_cov", us, f"B*={argmins}: {verdict}")]


def run_all():
    rows = []
    for fn in (
        bench_fig3_coverage,
        bench_fig6_scheme_ordering,
        bench_fig7_sexp_mean,
        bench_fig8_sexp_cov,
        bench_fig9_pareto_mean,
        bench_fig10_pareto_cov,
    ):
        rows.extend(fn())
    return rows
