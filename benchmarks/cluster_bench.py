"""Cluster-engine benchmark: §VII dynamics the closed forms cannot express.

Six scenarios on the synthetic Google-trace jobs (and parametric tails):

  * ``redundancy``   -- per trace job, engine mean compute time at B = N (no
    redundancy) vs the planned B*: reproduces the §VII observation that
    planned redundancy speeds heavy-tail jobs up by about an order of
    magnitude.
  * ``queueing``     -- Poisson multi-job arrivals: mean response time with
    and without planned redundancy (the queueing cost/benefit).
  * ``cancellation`` -- replica cancellation on/off: worker-seconds burned,
    seconds reclaimed, response-time delta.
  * ``churn``        -- worker fail/join churn on/off: failures, rescues,
    compute-time delta.
  * ``backend``      -- wall-clock of a full-frontier ``plan_cluster`` sweep
    on the Python event engine vs the vectorized jax backend
    (``repro.cluster.vectorized``): the speedup that makes thousand-candidate
    sweeps and per-window replanning affordable.  The CI regression gate
    (``benchmarks/check_bench_regression.py``) consumes this section.
  * ``dynamic``      -- the same full-frontier sweep under fail/join churn and
    heterogeneous worker speeds, scored by the Python event engine vs the jax
    epoch-scan step loop (``repro.cluster.epoch_scan``): the sweep regime that
    used to fall back to Python entirely.  Records warm speed edge (min-of-3),
    per-dist cold compile+run seconds, and the process peak-RSS column; the
    regression gate keys on the warm edge *and* the cold seconds.
  * ``speculation``  -- planned (proactive) vs speculative (reactive) vs
    hybrid redundancy across Exp/SExp/Pareto and the heavy trace job:
    mean/p95 compute time, worker-seconds, and backup counts per variant.
    The regression gate keys on the Pareto row (reactive backups must keep
    beating the no-redundancy baseline).
  * ``trace_scale``  -- a 10k-job synthetic cluster-day streamed through the
    O(slab)-memory jax path (``repro.cluster.stream``): the full
    (family x budget x scheduler) grid, gated on whole-grid warm wall time
    (single-digit seconds) and process peak RSS (the streaming-aggregation
    memory ceiling).
  * ``slo``          -- tail-SLO planning (``RedundancyPlanner.plan_slo``):
    cheapest feasible (B, r, scheduler) meeting a p99 response target under
    Poisson arrivals, per parametric tail family, on the streaming-quantile
    kernel.  Records the cheapest-feasible vs mean-optimal candidates and
    whether they differ; the regression gate keys on the Pareto row keeping
    the mean-optimal != tail-optimal divergence alive and on all families
    staying feasible.
  * ``space_sharing`` -- the space-sharing scheduler: mean response-time
    ratio of ``packed`` (narrow concurrent jobs on disjoint subsets) vs the
    ``fifo_gang`` baseline on one saturated workload, plus the jax-vs-python
    warm edge on a space-shared full-frontier ``plan_cluster`` sweep (the
    space lane of ``repro.cluster.epoch_scan`` vs the per-candidate Python
    engine).  The regression gate keys on both: packed must keep beating
    the gang, and the space lane must keep its speed edge.

``--smoke`` shrinks every sample count so the whole file runs in seconds --
CI executes it on every PR, gates on the JSON against the committed
``BENCH_cluster.json`` baseline, and uploads the artifact.  ``--backend``
selects which engine scores the ``redundancy`` scenario (the nightly job
runs ``--backend both``).
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import resource
import sys
import time

# The dynamic epoch scan is a long chain of tiny fused loops; XLA's legacy
# CPU runtime executes that shape 2-4x faster than the thunk runtime on the
# smoke sizes (measured on the committed baseline's machine), so pin it for
# benchmarking unless the caller already chose.  Must happen before jax
# initializes its backends.
if "xla_cpu_use_thunk_runtime" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_cpu_use_thunk_runtime=false"
    ).strip()

import jax
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.cluster import (
    ChurnProcess,
    ClusterEngine,
    Job,
    Scenario,
    jobs_from_traces,
    sample_job_times,
    simulate_fifo,
)
from repro.core import traces
from repro.core.planner import RedundancyPlanner
from repro.core.service_time import Empirical, Exponential, Pareto, ShiftedExponential

ART = pathlib.Path(__file__).resolve().parent / "artifacts" / "cluster"


def _cfg(smoke: bool) -> dict:
    if smoke:
        return {
            "n_workers": 10,
            "n_reps": 60,
            "n_jobs": 6,
            "trace_jobs": 4,
            "backend_workers": 24,
            "backend_reps": 800,
            "dyn_workers": 12,
            "dyn_reps": 960,
            "space_workers": 12,
            "space_reps": 768,
            # the trace section streams the REAL 10k-job day even in smoke:
            # the whole grid is ~2s warm, and the acceptance gate is about
            # the full-scale stream, not a toy one.  Smoke only shrinks the
            # cluster (fewer pools -> smaller carry, same stream length).
            "trace_stream_jobs": 10_000,
            "trace_stream_reps": 2,
            "trace_slab": 1024,
            "trace_pool": 6,
            "trace_pools": 96,
            "slo_workers": 8,
            "slo_jobs": 600,
            "slo_reps": 2,
        }
    return {
        "n_workers": 20,
        "n_reps": 400,
        "n_jobs": 24,
        "trace_jobs": 10,
        "backend_workers": 36,
        "backend_reps": 1000,
        "dyn_workers": 16,
        "dyn_reps": 2048,
        "space_workers": 16,
        "space_reps": 2048,
        "trace_stream_jobs": 10_000,
        "trace_stream_reps": 2,
        "trace_slab": 1024,
        "trace_pool": 6,
        "trace_pools": 2304,
        "slo_workers": 8,
        "slo_jobs": 2000,
        "slo_reps": 4,
    }


def bench_redundancy(cfg: dict, seed: int = 0, backend: str = "python") -> dict:
    """Engine-measured speedup of planned redundancy vs no redundancy."""
    n = cfg["n_workers"]
    jobs = traces.synthetic_google_jobs()
    # interleave the exponential (1-4) and heavy (5-10) families so that
    # smoke subsets still exercise both tail regimes
    exp = [j for j in jobs if j.family == "exponential"]
    heavy = [j for j in jobs if j.family == "heavy"]
    interleaved = [j for pair in zip(heavy, exp) for j in pair] + heavy[len(exp):]
    jobs = interleaved[: cfg["trace_jobs"]]
    planner = RedundancyPlanner(n)
    out = {}
    for i, tj in enumerate(jobs):
        dist = Empirical(samples=tuple(float(x) for x in tj.task_times))
        plan = planner.plan_empirical(tj.task_times, "mean", n_mc=4 * cfg["n_reps"], seed=seed)
        t_base = sample_job_times(dist, n, n, cfg["n_reps"], seed=seed + i, backend=backend)
        t_plan = sample_job_times(
            dist, n, plan.n_batches, cfg["n_reps"], seed=seed + i, backend=backend
        )
        out[tj.name] = {
            "family": tj.family,
            "B_star": plan.n_batches,
            "mean_T_no_redundancy": float(t_base.mean()),
            "mean_T_planned": float(t_plan.mean()),
            "speedup": float(t_base.mean() / t_plan.mean()),
        }
    heavy = [v["speedup"] for v in out.values() if v["family"] == "heavy"]
    out["_summary"] = {
        "max_heavy_speedup": max(heavy) if heavy else None,
        "min_heavy_speedup": min(heavy) if heavy else None,
    }
    return out


def bench_queueing(cfg: dict, seed: int = 0) -> dict:
    """Multi-job FIFO queueing under Poisson arrivals, planned vs none."""
    n = cfg["n_workers"]
    trace = traces.synthetic_google_jobs()[5]  # heavy-tail job
    plan = RedundancyPlanner(n).plan_empirical(trace.task_times, "mean", n_mc=2000, seed=seed)
    base_mean = float(np.mean(trace.task_times))
    # arrivals fast enough that queueing matters: ~1 job per planned job-time
    rate = 1.0 / (base_mean * 2.0)
    workload = jobs_from_traces([trace] * cfg["n_jobs"], n, rate, seed=seed)
    out = {}
    for label, b in [("no_redundancy", n), ("planned", plan.n_batches)]:
        rep = ClusterEngine(n, seed=seed, n_batches=b, cancel_redundant=True).run(workload)
        resp = rep.response_times
        resp = resp[np.isfinite(resp)]
        out[label] = {
            "B": b,
            "mean_response": float(resp.mean()),
            "p95_response": float(np.percentile(resp, 95)),
            "worker_seconds": rep.worker_seconds,
        }
    base, planned = out["no_redundancy"]["mean_response"], out["planned"]["mean_response"]
    out["response_speedup"] = base / planned
    return out


def bench_cancellation(cfg: dict, seed: int = 0) -> dict:
    """Worker-seconds reclaimed by cancelling redundant replicas."""
    n = cfg["n_workers"]
    dist = Pareto(sigma=1.0, alpha=1.8)
    jobs = [Job(job_id=i, dist=dist, n_tasks=n) for i in range(cfg["n_jobs"])]
    out = {}
    for label, cancel in [("cancel_on", True), ("cancel_off", False)]:
        rep = ClusterEngine(n, seed=seed, n_batches=max(1, n // 4), cancel_redundant=cancel).run(
            jobs
        )
        out[label] = {
            "worker_seconds": rep.worker_seconds,
            "saved_seconds": rep.cancelled_seconds_saved,
            "mean_response": float(rep.response_times.mean()),
        }
    out["worker_seconds_ratio"] = (
        out["cancel_on"]["worker_seconds"] / out["cancel_off"]["worker_seconds"]
    )
    return out


def bench_churn(cfg: dict, seed: int = 0) -> dict:
    """Fail/join churn: completion under failures, rescue accounting."""
    n = cfg["n_workers"]
    dist = Pareto(sigma=1.0, alpha=1.8)
    jobs = [Job(job_id=i, dist=dist, n_tasks=n) for i in range(cfg["n_jobs"])]
    out = {}
    scenarios = [
        ("churn_off", None),
        ("churn_on", ChurnProcess(fail_rate=0.02, mean_downtime=5.0)),
    ]
    for label, churn in scenarios:
        rep = ClusterEngine(n, seed=seed, n_batches=max(1, n // 4), churn=churn).run(jobs)
        t = rep.compute_times
        out[label] = {
            "mean_compute": float(t[np.isfinite(t)].mean()),
            "n_worker_failures": rep.n_worker_failures,
            "n_replicas_rescued": rep.n_replicas_rescued,
            "all_jobs_completed": bool(np.isfinite(t).all()),
        }
    out["churn_slowdown"] = out["churn_on"]["mean_compute"] / out["churn_off"]["mean_compute"]
    return out


def bench_backend(cfg: dict, seed: int = 0) -> dict:
    """Full-frontier ``plan_cluster`` sweep: Python event engine vs jax.

    Wall-clock for scoring every feasible B of ``backend_workers`` workers
    with ``backend_reps`` Monte-Carlo reps each.  The jax backend is timed
    warm (one untimed call first, reported as ``jax_seconds_cold``): the
    compile amortizes across every subsequent sweep of the same shape, which
    is exactly how ``plan_sweep`` / the online replanner use it.
    """
    n, reps = cfg["backend_workers"], cfg["backend_reps"]
    out = {"n_workers": n, "n_reps": reps, "dists": {}}
    for name, dist in [("exponential", Exponential(1.0)), ("pareto_heavy", Pareto(1.0, 1.8))]:
        planner = RedundancyPlanner(n)
        jax.clear_caches()  # same frontier shapes across dists: force a real compile
        t0 = time.time()
        planner.plan_cluster(dist, n_reps=reps, seed=seed, backend="jax")
        cold = time.time() - t0
        t0 = time.time()
        plan_jax = planner.plan_cluster(dist, n_reps=reps, seed=seed, backend="jax")
        t_jax = time.time() - t0
        t0 = time.time()
        plan_py = planner.plan_cluster(dist, n_reps=reps, seed=seed, backend="python")
        t_py = time.time() - t0
        out["dists"][name] = {
            "frontier_size": len(planner.candidates),
            "python_seconds": t_py,
            "jax_seconds_warm": t_jax,
            "jax_seconds_cold": cold,
            "speedup_warm": t_py / max(t_jax, 1e-9),
            "speedup_cold": t_py / max(cold, 1e-9),
            "B_python": plan_py.n_batches,
            "B_jax": plan_jax.n_batches,
        }
    speedups = [d["speedup_warm"] for d in out["dists"].values()]
    out["min_speedup_warm"] = min(speedups)
    out["max_speedup_warm"] = max(speedups)
    return out


def bench_dynamic(cfg: dict, seed: int = 0) -> dict:
    """Churned + heterogeneous full-frontier ``plan_cluster``: python vs jax.

    The scenario PR 2 could not vectorize: every candidate B scored under
    worker fail/join churn (with replica rescue) on a heterogeneous-speed
    cluster.  The Python engine replays one event loop per candidate; the jax
    epoch scan (``repro.cluster.epoch_scan``) batches the whole frontier's
    correlated job streams into one ``lax.scan`` device call.  Warm timing,
    like ``bench_backend``: the compile amortizes across every sweep of the
    same shape (exactly how ``plan_sweep`` and nightly grids use it).
    """
    from repro.cluster.epoch_scan import clear_runner_cache

    n, reps = cfg["dyn_workers"], cfg["dyn_reps"]
    churn = ChurnProcess(fail_rate=0.02, mean_downtime=2.0)
    rng = np.random.default_rng(seed)
    speeds = tuple(float(s) for s in rng.uniform(0.5, 2.0, size=n))
    out = {"n_workers": n, "n_reps": reps, "churn_fail_rate": churn.fail_rate, "dists": {}}
    for name, dist in [("exponential", Exponential(1.0)), ("pareto_heavy", Pareto(1.0, 1.8))]:
        planner = RedundancyPlanner(n)
        # 2 fail/join pairs per worker comfortably cover each stream's horizon
        # (~1 expected failure); 96-job streams keep the step loop dominated
        # by job dispatches rather than churn-boundary bookkeeping
        sc = Scenario(churn=churn, speeds=speeds)
        kw = dict(n_reps=reps, seed=seed, scenario=sc)
        kw_jax = dict(kw, scenario=sc.replace(churn_pairs_per_worker=2, jobs_per_stream=96))
        clear_runner_cache()
        jax.clear_caches()  # same shapes across dists: force a real compile
        t0 = time.time()
        planner.plan_cluster(dist, **kw_jax, backend="jax")
        cold = time.time() - t0
        # min-of-3 warm: the jax call is tens of milliseconds, where shared
        # CI runners add multiplicative noise the long python run averages out
        warms = []
        for _ in range(3):
            t0 = time.time()
            plan_jax = planner.plan_cluster(dist, **kw_jax, backend="jax")
            warms.append(time.time() - t0)
        t_jax = min(warms)
        t0 = time.time()
        plan_py = planner.plan_cluster(dist, **kw, backend="python")
        t_py = time.time() - t0
        out["dists"][name] = {
            "frontier_size": len(planner.candidates),
            "python_seconds": t_py,
            "jax_seconds_warm": t_jax,
            "jax_seconds_cold": cold,
            "speedup_warm": t_py / max(t_jax, 1e-9),
            "speedup_cold": t_py / max(cold, 1e-9),
            "B_python": plan_py.n_batches,
            "B_jax": plan_jax.n_batches,
        }
    speedups = [d["speedup_warm"] for d in out["dists"].values()]
    out["min_speedup_warm"] = min(speedups)
    out["max_speedup_warm"] = max(speedups)
    out["max_cold_seconds"] = max(d["jax_seconds_cold"] for d in out["dists"].values())
    # process high-water RSS right after the dynamic sweeps: the chunked-rep
    # memory story's observable (ru_maxrss is KiB on Linux, bytes on macOS)
    rss_scale = 1024.0**2 if sys.platform == "darwin" else 1024.0
    out["peak_rss_mb"] = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / rss_scale
    return out


def bench_space_sharing(cfg: dict, seed: int = 0) -> dict:
    """Space-sharing scheduler: packed-vs-gang response ratio + jax edge.

    Two measurements: (1) the scheduling effect itself -- a saturated stream
    of narrow jobs (``workers_per_job = n/3``) finishes with a much lower
    mean response under ``packed`` space sharing than under the whole-cluster
    FIFO gang, because disjoint subsets run three jobs at once; (2) the
    backend effect -- scoring a space-shared candidate frontier on the jax
    space lane vs one Python event loop per candidate (warm min-of-3, like
    ``bench_dynamic``; cold = compile+run).  The regression gate keys on the
    response ratio staying below 1 with margin and the warm edge floor.
    """
    from repro.cluster.epoch_scan import clear_runner_cache
    from repro.core import analysis

    n, reps = cfg["space_workers"], cfg["space_reps"]
    wpj = max(2, n // 3)
    n_jobs = 24
    arr = np.zeros(n_jobs)
    d_ratio = Pareto(1.0, 1.8)
    gang = simulate_fifo(d_ratio, n, 2, arr, max(64, reps // 8), seed=seed)
    packed = simulate_fifo(
        d_ratio, n, 2, arr, max(64, reps // 8), seed=seed,
        scheduler="packed", workers_per_job=wpj,
    )
    ratio = float(packed.response_times.mean() / gang.response_times.mean())
    out = {
        "n_workers": n,
        "n_reps": reps,
        "workers_per_job": wpj,
        "response_ratio_packed_vs_gang": ratio,
        "dists": {},
    }
    cands = analysis.feasible_B(wpj)
    for name, dist in [("exponential", Exponential(1.0)), ("pareto_heavy", Pareto(1.0, 1.8))]:
        planner = RedundancyPlanner(n, candidates=cands)
        kw = dict(
            n_reps=reps,
            seed=seed,
            scenario=Scenario(scheduler="packed", workers_per_job=wpj, jobs_per_stream=48),
        )
        clear_runner_cache()
        jax.clear_caches()  # same shapes across dists: force a real compile
        t0 = time.time()
        planner.plan_cluster(dist, **kw, backend="jax")
        cold = time.time() - t0
        warms = []
        for _ in range(3):
            t0 = time.time()
            plan_jax = planner.plan_cluster(dist, **kw, backend="jax")
            warms.append(time.time() - t0)
        t_jax = min(warms)
        t0 = time.time()
        plan_py = planner.plan_cluster(dist, **kw, backend="python")
        t_py = time.time() - t0
        out["dists"][name] = {
            "frontier_size": len(cands),
            "python_seconds": t_py,
            "jax_seconds_warm": t_jax,
            "jax_seconds_cold": cold,
            "speedup_warm": t_py / max(t_jax, 1e-9),
            "B_python": plan_py.n_batches,
            "B_jax": plan_jax.n_batches,
        }
    speedups = [d["speedup_warm"] for d in out["dists"].values()]
    out["min_speedup_warm"] = min(speedups)
    out["max_speedup_warm"] = max(speedups)
    out["max_cold_seconds"] = max(d["jax_seconds_cold"] for d in out["dists"].values())
    return out


def bench_speculation(cfg: dict, seed: int = 0) -> dict:
    """Planned vs speculative vs hybrid redundancy across tail regimes.

    The paper's planned replication spends workers *proactively*; the
    ``Speculation`` policy spends them *reactively*, backing up only the
    replicas whose elapsed time crosses ``theta x`` the running median of
    completed siblings.  Four variants per distribution, all on the Python
    event engine (the reference semantics the jax scan and the live runtime
    are pinned to):

      no_redundancy  B = N, no backups   -- the straggler-exposed baseline
      planned        B = B*, no backups  -- §VI/§VII proactive replication
      speculative    B = N, backups      -- reactive only
      hybrid         B = B*, backups     -- both

    The check interval scales with each distribution's median task time so
    one policy spec covers sub-second exponentials and the ~14 s-median
    trace job alike.  The regression gate keys on the Pareto row: reactive
    backups alone must keep beating the no-redundancy baseline.
    """
    from repro.cluster import Speculation

    n = cfg["n_workers"]
    n_jobs = cfg["n_reps"]
    theta, min_obs = 2.0, 3
    dists = [
        ("exponential", Exponential(1.0)),
        ("shifted_exp", ShiftedExponential(0.3, 1.0)),
        ("pareto_heavy", Pareto(1.0, 1.5)),
    ]
    trace = traces.synthetic_google_jobs()[5]  # heavy-tail trace job
    dists.append(("trace_heavy", Empirical(samples=tuple(float(x) for x in trace.task_times))))
    out = {
        "n_workers": n,
        "n_jobs": n_jobs,
        "theta": theta,
        "min_observations": min_obs,
        "dists": {},
    }
    for name, dist in dists:
        med = float(np.median(dist.sample_np(np.random.default_rng(seed), (512,))))
        spec = Speculation(
            interval=max(0.05, 0.25 * med), theta=theta, min_observations=min_obs
        )
        planner = RedundancyPlanner(n)
        if isinstance(dist, Empirical):
            plan = planner.plan_empirical(
                np.asarray(dist.samples), "mean", n_mc=4 * n_jobs, seed=seed
            )
        else:
            plan = planner.plan(dist, objective="mean")
        variants = {
            "no_redundancy": (n, None),
            "planned": (plan.n_batches, None),
            "speculative": (n, spec),
            "hybrid": (plan.n_batches, spec),
        }
        entry = {"B_star": plan.n_batches, "interval": spec.interval}
        for label, (b, sp) in variants.items():
            rep = ClusterEngine(
                n, seed=seed, n_batches=b, cancel_redundant=True, speculation=sp
            ).run([Job(job_id=i, dist=dist, n_tasks=n) for i in range(n_jobs)])
            t = rep.compute_times
            entry[label] = {
                "B": b,
                "mean_compute": float(t.mean()),
                "p95_compute": float(np.percentile(t, 95)),
                "worker_seconds": rep.worker_seconds,
                "n_speculative": rep.n_speculative,
            }
        base = entry["no_redundancy"]["mean_compute"]
        for label in ("planned", "speculative", "hybrid"):
            entry[f"speedup_{label}"] = base / entry[label]["mean_compute"]
        out["dists"][name] = entry
    out["pareto_speculative_speedup"] = out["dists"]["pareto_heavy"]["speedup_speculative"]
    out["pareto_hybrid_speedup"] = out["dists"]["pareto_heavy"]["speedup_hybrid"]
    return out


def bench_trace_scale(cfg: dict, seed: int = 0) -> dict:
    """Trace-scale throughput: a 10k-job cluster-day through the stream path.

    The full (distribution family x budget x scheduler) grid -- 12 cells --
    over one synthetic cluster-day per family, on a trace-sized cluster
    (``trace_pools`` pools of ``trace_pool`` workers; the 2011 Google trace
    holds ~12.5k machines).  Every cell streams the whole day through
    ``simulate_stream``: draws generated per slab, statistics carried in the
    scan, so peak memory is O(slab) regardless of the stream length.

    Two gates (``check_bench_regression.py``):

      * ``sweep_seconds_warm`` -- min-of-3 full-grid wall time after the cold
        pass compiled the six kernel shapes (families reuse compiles).  The
        whole cluster-day grid must stay single-digit seconds warm.
      * ``peak_rss_mb`` -- process high-water RSS after the sweep; the O(slab)
        memory story's observable.  A materialized (reps x jobs x B x r) path
        would blow straight through the ceiling.

    ``fifo_gang`` cells run one pool-width gang (the exact ``simulate_fifo``
    regime); ``packed``/``balanced`` split the cluster into disjoint pools.
    """
    from repro.cluster import simulate_stream
    from repro.core.traces import synthetic_cluster_day

    pool = cfg["trace_pool"]
    n_jobs = cfg["trace_stream_jobs"]
    reps = cfg["trace_stream_reps"]
    slab = cfg["trace_slab"]
    n_workers = pool * cfg["trace_pools"]
    days = {
        fam: synthetic_cluster_day(n_jobs=n_jobs, seed=seed + 7, families=(fam,))
        for fam in ("exponential", "heavy")
    }
    budgets = {"planned": pool // 2, "no_redundancy": pool}

    def sweep() -> dict:
        cells = {}
        for fam, day in days.items():
            for sched in ("fifo_gang", "packed", "balanced"):
                gang = sched == "fifo_gang"
                for bname, b in budgets.items():
                    sc = Scenario(
                        outputs="stream",
                        scheduler=sched,
                        workers_per_job=None if gang else pool,
                        cancel_redundant=True,
                    )
                    stats = simulate_stream(
                        day, pool if gang else n_workers, b, reps,
                        scenario=sc, slab=slab,
                    )
                    s = stats.summary()
                    cells[f"{fam}/{sched}/{bname}"] = {
                        "B": b,
                        "r": pool // b,
                        "mean_response": s["mean_response"],
                        "p99_response": s["p99_response"],
                        "worker_seconds": s["worker_seconds"],
                        "cancelled_seconds_saved": s["cancelled_seconds_saved"],
                    }
        return cells

    jax.clear_caches()  # force real compiles into the cold pass
    t0 = time.time()
    cells = sweep()
    cold = time.time() - t0
    warms = []
    for _ in range(3):
        t0 = time.time()
        cells = sweep()
        warms.append(time.time() - t0)
    rss_scale = 1024.0**2 if sys.platform == "darwin" else 1024.0
    return {
        "n_jobs": n_jobs,
        "n_reps": reps,
        "slab": slab,
        "pool_width": pool,
        "n_pools": cfg["trace_pools"],
        "n_cells": len(cells),
        "cells": cells,
        "sweep_seconds_cold": cold,
        "sweep_seconds_warm": min(warms),
        "jobs_per_second_warm": len(cells) * n_jobs * reps / max(min(warms), 1e-9),
        "peak_rss_mb": resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / rss_scale,
    }


def bench_slo(cfg: dict, seed: int = 0) -> dict:
    """Tail-SLO planning: cheapest feasible (B, r, scheduler) per tail family.

    Runs ``RedundancyPlanner.plan_slo`` over the (scheduler x pool-width x B)
    grid for the three parametric tails and records, per family, the cheapest
    feasible candidate, the mean-optimal candidate, and whether they differ --
    the paper's "mean-optimal is not tail-optimal" observation, kept live as
    a gated benchmark fact (the gate keys on the Pareto row).
    """
    from repro.cluster import SLO

    n = cfg["slo_workers"]
    planner = RedundancyPlanner(n)
    rate = 0.05
    # p99 response targets sized so each family is feasible at the committed
    # smoke scale but tight enough that heavy tails need planning to meet it
    dists = {
        "exponential": (Exponential(1.0), 12.0),
        "shifted_exp": (ShiftedExponential(0.3, 1.0), 15.0),
        "pareto_heavy": (Pareto(1.0, 1.5), 60.0),
    }

    def sweep() -> dict:
        return {
            name: planner.plan_slo(
                [dist],
                SLO(quantile=0.99, target_s=target, arrival_rate=rate),
                n_jobs=cfg["slo_jobs"],
                n_reps=cfg["slo_reps"],
                seed=seed,
                schedulers=("fifo_gang", "packed"),
            )
            for name, (dist, target) in dists.items()
        }

    jax.clear_caches()
    t0 = time.time()
    plans = sweep()
    cold = time.time() - t0
    t0 = time.time()
    plans = sweep()
    warm = time.time() - t0

    def _cand(c) -> dict:
        return {
            "scheduler": c.scheduler,
            "workers_per_job": c.workers_per_job,
            "B": c.n_batches,
            "r": c.replication,
            "feasible": c.feasible,
            "cost_worker_seconds": c.cost_worker_seconds,
            "mean_response": c.mean_response,
            "achieved_p99": c.achieved[0],
        }

    def _key(c) -> tuple:
        return (c.scheduler, c.workers_per_job, c.n_batches, c.replication)

    out: dict = {"n_workers": n, "arrival_rate": rate, "quantile": 0.99}
    feas_total = cand_total = 0
    for name, plan in plans.items():
        mean_opt = min(plan.candidates, key=lambda c: c.mean_response)
        n_feas = sum(c.feasible for c in plan.candidates)
        feas_total += n_feas
        cand_total += len(plan.candidates)
        out[name] = {
            "target_p99_s": dists[name][1],
            "feasible": plan.feasible,
            "n_candidates": len(plan.candidates),
            "n_feasible": n_feas,
            "best": None if plan.best is None else _cand(plan.best),
            "mean_optimal": _cand(mean_opt),
            "mean_vs_tail_diverge": plan.best is not None
            and _key(plan.best) != _key(mean_opt),
        }
    out["feasible_frac"] = feas_total / max(cand_total, 1)
    out["all_feasible"] = all(out[name]["feasible"] for name in dists)
    out["pareto_mean_vs_tail_diverge"] = out["pareto_heavy"]["mean_vs_tail_diverge"]
    out["sweep_seconds_cold"] = cold
    out["sweep_seconds_warm"] = warm
    return out


def run_all(smoke: bool = True, seed: int = 0) -> list:
    """CSV rows for the benchmark aggregator (smoke sizes by default)."""
    cfg = _cfg(smoke)
    rows = []
    t0 = time.time()
    red = bench_redundancy(cfg, seed)
    s = red["_summary"]
    rows.append(
        (
            "cluster_redundancy",
            (time.time() - t0) * 1e6 / max(cfg["trace_jobs"], 1),
            f"heavy speedup {s['min_heavy_speedup']:.1f}x..{s['max_heavy_speedup']:.1f}x",
        )
    )
    t0 = time.time()
    q = bench_queueing(cfg, seed)
    rows.append(
        (
            "cluster_queueing",
            (time.time() - t0) * 1e6 / cfg["n_jobs"],
            f"response speedup {q['response_speedup']:.1f}x (B*={q['planned']['B']})",
        )
    )
    t0 = time.time()
    c = bench_cancellation(cfg, seed)
    rows.append(
        (
            "cluster_cancellation",
            (time.time() - t0) * 1e6 / cfg["n_jobs"],
            f"worker-seconds x{c['worker_seconds_ratio']:.2f} with cancellation",
        )
    )
    t0 = time.time()
    ch = bench_churn(cfg, seed)
    rows.append(
        (
            "cluster_churn",
            (time.time() - t0) * 1e6 / cfg["n_jobs"],
            f"slowdown x{ch['churn_slowdown']:.2f} under churn "
            f"({ch['churn_on']['n_worker_failures']} failures)",
        )
    )
    t0 = time.time()
    bk = bench_backend(cfg, seed)
    rows.append(
        (
            "cluster_backend",
            (time.time() - t0) * 1e6 / max(cfg["backend_reps"], 1),
            f"jax frontier sweep {bk['min_speedup_warm']:.0f}x"
            f"..{bk['max_speedup_warm']:.0f}x vs python engine",
        )
    )
    t0 = time.time()
    dy = bench_dynamic(cfg, seed)
    rows.append(
        (
            "cluster_dynamic",
            (time.time() - t0) * 1e6 / max(cfg["dyn_reps"], 1),
            f"churned/hetero sweep {dy['min_speedup_warm']:.0f}x"
            f"..{dy['max_speedup_warm']:.0f}x vs python engine",
        )
    )
    t0 = time.time()
    sk = bench_speculation(cfg, seed)
    rows.append(
        (
            "cluster_speculation",
            (time.time() - t0) * 1e6 / max(cfg["n_reps"], 1),
            f"pareto: speculative x{sk['pareto_speculative_speedup']:.2f}, "
            f"hybrid x{sk['pareto_hybrid_speedup']:.2f} vs no redundancy",
        )
    )
    t0 = time.time()
    sp = bench_space_sharing(cfg, seed)
    rows.append(
        (
            "cluster_space_sharing",
            (time.time() - t0) * 1e6 / max(cfg["space_reps"], 1),
            f"packed/gang response x{sp['response_ratio_packed_vs_gang']:.2f}, "
            f"jax space sweep {sp['min_speedup_warm']:.0f}x"
            f"..{sp['max_speedup_warm']:.0f}x",
        )
    )
    t0 = time.time()
    tr = bench_trace_scale(cfg, seed)
    rows.append(
        (
            "cluster_trace_scale",
            (time.time() - t0) * 1e6 / max(cfg["trace_stream_jobs"], 1),
            f"{tr['n_cells']}-cell day sweep {tr['sweep_seconds_warm']:.1f}s warm "
            f"({tr['jobs_per_second_warm'] / 1e3:.0f}k jobs/s, "
            f"rss {tr['peak_rss_mb']:.0f}MB)",
        )
    )
    t0 = time.time()
    sl = bench_slo(cfg, seed)
    rows.append(
        (
            "cluster_slo",
            (time.time() - t0) * 1e6 / max(cfg["slo_jobs"], 1),
            f"p99 plans feasible {sl['feasible_frac']:.0%} of grid, "
            f"pareto mean!=tail: {sl['pareto_mean_vs_tail_diverge']}",
        )
    )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny sample counts (CI)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--backend",
        choices=["python", "jax", "both"],
        default="python",
        help="engine scoring the redundancy scenario (the backend section always runs both)",
    )
    ap.add_argument("--out", type=pathlib.Path, default=ART / "cluster_bench.json")
    args = ap.parse_args()

    cfg = _cfg(args.smoke)
    t0 = time.time()
    result = {
        "config": {"smoke": args.smoke, "seed": args.seed, "backend": args.backend, **cfg},
        "queueing": bench_queueing(cfg, args.seed),
        "cancellation": bench_cancellation(cfg, args.seed),
        "churn": bench_churn(cfg, args.seed),
        "backend": bench_backend(cfg, args.seed),
        "dynamic": bench_dynamic(cfg, args.seed),
        "space_sharing": bench_space_sharing(cfg, args.seed),
        "speculation": bench_speculation(cfg, args.seed),
        "trace_scale": bench_trace_scale(cfg, args.seed),
        "slo": bench_slo(cfg, args.seed),
    }
    if args.backend in ("python", "both"):
        result["redundancy"] = bench_redundancy(cfg, args.seed, backend="python")
    if args.backend in ("jax", "both"):
        result["redundancy_jax"] = bench_redundancy(cfg, args.seed, backend="jax")
    if "redundancy" not in result:
        # the regression gate keys on "redundancy"; alias the jax run
        result["redundancy"] = result["redundancy_jax"]
    result["wall_seconds"] = time.time() - t0

    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(result, indent=2))
    print(json.dumps(result, indent=2))
    print(f"\nwrote {args.out}", file=sys.stderr)


if __name__ == "__main__":
    main()
