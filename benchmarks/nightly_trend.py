"""Nightly-bench trend summary: bench JSONs -> one markdown table.

The nightly workflow keeps a 90-day series of ``cluster_bench.py``
artifacts; this script folds any number of those JSONs (a directory of
downloaded artifacts, or just the fresh run) into a compact markdown table
of the load-bearing series -- the jax speed edges (static + dynamic + space
sweeps), the packed-vs-gang response ratio, the dynamic cold start, and the
heavy-tail redundancy speedup.  Rows are labelled by the run id carried in
the artifact path (``gh run download`` lands each artifact in its own
directory) and sorted naturally, so the table reads chronologically.

Usage::

    python benchmarks/nightly_trend.py artifacts_dir_or_json [more ...]
    python benchmarks/nightly_trend.py bench-history fresh.json >> "$GITHUB_STEP_SUMMARY"

The nightly workflow downloads the retained artifact series into
``bench-history/run-<id>/`` and points this script at the directory plus the
fresh run's JSON.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import re
import sys


def _natkey(label: str) -> tuple:
    """Natural sort key: digit runs compare numerically (run-9 < run-10)."""
    return tuple(
        int(chunk) if chunk.isdigit() else chunk
        for chunk in re.split(r"(\d+)", label)
    )


def _label(root: pathlib.Path, f: pathlib.Path) -> str:
    """Row label for one bench JSON: the most specific path component that
    carries a run id (a digit sequence), falling back to the stem.  Keeps
    downloaded-artifact layouts (``run-<id>/<artifact>/bench.json``, where
    every stem is identical) distinguishable in the table."""
    parts = (f.relative_to(root).parts if root.is_dir() else ()) + (f.stem,)
    for part in parts:
        if any(c.isdigit() for c in part):
            return part.removesuffix(".json")
    return f.stem


def _load(paths: list[pathlib.Path]) -> list[tuple[str, dict]]:
    rows = []
    for p in paths:
        candidates = sorted(p.glob("**/*.json")) if p.is_dir() else [p]
        for f in candidates:
            try:
                rows.append((_label(p, f), json.loads(f.read_text())))
            except (OSError, json.JSONDecodeError) as ex:
                print(f"skipping {f}: {ex}", file=sys.stderr)
    # run-id labels sort naturally; a digit-less label is the freshly
    # produced run (tonight's JSON has no run id yet -- the artifact name
    # gains one only on upload) and belongs at the bottom, newest last
    rows.sort(key=lambda r: (0 if any(c.isdigit() for c in r[0]) else 1, _natkey(r[0])))
    return rows


def _get(d: dict, *keys, default=None):
    for k in keys:
        if not isinstance(d, dict) or k not in d:
            return default
        d = d[k]
    return d


def trend_table(rows: list[tuple[str, dict]]) -> str:
    """Markdown table over the load-bearing nightly series."""
    header = (
        "| run | static edge (min..max) | dynamic edge (min..max) "
        "| space edge (min..max) | packed/gang resp | dynamic cold (s) "
        "| peak RSS (MB) | heavy-tail speedup |\n"
        "|---|---|---|---|---|---|---|---|"
    )
    lines = [header]
    for name, d in rows:
        b = _get(d, "backend") or {}
        dy = _get(d, "dynamic") or {}
        sp = _get(d, "space_sharing") or {}
        heavy = _get(d, "redundancy", "_summary", "max_heavy_speedup")

        def fmt(v, spec=".1f", suffix=""):
            return format(v, spec) + suffix if isinstance(v, (int, float)) else "-"

        lines.append(
            "| {} | {}..{} | {}..{} | {}..{} | {} | {} | {} | {} |".format(
                name,
                fmt(b.get("min_speedup_warm"), ".0f", "x"),
                fmt(b.get("max_speedup_warm"), ".0f", "x"),
                fmt(dy.get("min_speedup_warm"), ".0f", "x"),
                fmt(dy.get("max_speedup_warm"), ".0f", "x"),
                fmt(sp.get("min_speedup_warm"), ".0f", "x"),
                fmt(sp.get("max_speedup_warm"), ".0f", "x"),
                fmt(sp.get("response_ratio_packed_vs_gang"), ".2f", "x"),
                fmt(dy.get("max_cold_seconds"), ".2f"),
                fmt(dy.get("peak_rss_mb"), ".0f"),
                fmt(heavy, ".2f", "x"),
            )
        )
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="+", type=pathlib.Path, help="bench JSONs or dirs")
    args = ap.parse_args()
    rows = _load(args.paths)
    if not rows:
        print("no bench JSONs found", file=sys.stderr)
        return 1
    print("### cluster bench trend\n")
    print(trend_table(rows))
    return 0


if __name__ == "__main__":
    sys.exit(main())
