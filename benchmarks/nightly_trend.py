"""Nightly-bench trend summary: bench JSONs -> one markdown table.

First step toward the ROADMAP's dashboard item: the nightly workflow keeps a
90-day series of ``cluster_bench.py`` artifacts; this script folds any number
of those JSONs (a directory of downloaded artifacts, or just the fresh run)
into a compact markdown table of the load-bearing series -- the jax speed
edges (static + dynamic sweeps), the dynamic cold start, and the heavy-tail
redundancy speedup -- sorted by each file's recorded timestamp-ish name.

Usage::

    python benchmarks/nightly_trend.py artifacts_dir_or_json [more ...]
    python benchmarks/nightly_trend.py bench.json >> "$GITHUB_STEP_SUMMARY"

For the full trend, download the artifact series first (e.g. ``gh run
download --name cluster-bench-nightly -D artifacts/``) and point this at the
directory.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def _load(paths: list[pathlib.Path]) -> list[tuple[str, dict]]:
    rows = []
    for p in paths:
        candidates = sorted(p.glob("**/*.json")) if p.is_dir() else [p]
        for f in candidates:
            try:
                rows.append((f.stem, json.loads(f.read_text())))
            except (OSError, json.JSONDecodeError) as ex:
                print(f"skipping {f}: {ex}", file=sys.stderr)
    return rows


def _get(d: dict, *keys, default=None):
    for k in keys:
        if not isinstance(d, dict) or k not in d:
            return default
        d = d[k]
    return d


def trend_table(rows: list[tuple[str, dict]]) -> str:
    """Markdown table over the load-bearing nightly series."""
    header = (
        "| run | static edge (min..max) | dynamic edge (min..max) "
        "| dynamic cold (s) | peak RSS (MB) | heavy-tail speedup |\n"
        "|---|---|---|---|---|---|"
    )
    lines = [header]
    for name, d in rows:
        b = _get(d, "backend") or {}
        dy = _get(d, "dynamic") or {}
        heavy = _get(d, "redundancy", "_summary", "max_heavy_speedup")

        def fmt(v, spec=".1f", suffix=""):
            return format(v, spec) + suffix if isinstance(v, (int, float)) else "-"

        lines.append(
            "| {} | {}..{} | {}..{} | {} | {} | {} |".format(
                name,
                fmt(b.get("min_speedup_warm"), ".0f", "x"),
                fmt(b.get("max_speedup_warm"), ".0f", "x"),
                fmt(dy.get("min_speedup_warm"), ".0f", "x"),
                fmt(dy.get("max_speedup_warm"), ".0f", "x"),
                fmt(dy.get("max_cold_seconds"), ".2f"),
                fmt(dy.get("peak_rss_mb"), ".0f"),
                fmt(heavy, ".2f", "x"),
            )
        )
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="+", type=pathlib.Path, help="bench JSONs or dirs")
    args = ap.parse_args()
    rows = _load(args.paths)
    if not rows:
        print("no bench JSONs found", file=sys.stderr)
        return 1
    print("### cluster bench trend\n")
    print(trend_table(rows))
    return 0


if __name__ == "__main__":
    sys.exit(main())
