"""Nightly-bench trend summary: bench JSONs -> markdown table + sparklines.

The nightly workflow keeps a 90-day series of ``cluster_bench.py``
artifacts; this script folds any number of those JSONs (a directory of
downloaded artifacts, or just the fresh run) into a compact markdown table
of the load-bearing series -- the jax speed edges (static + dynamic + space
sweeps), the packed-vs-gang response ratio, the dynamic cold start, the
trace-scale cluster-day sweep (warm seconds + peak RSS), the heavy-tail
redundancy speedup, the speculative-vs-planned Pareto speedups, and the
tail-SLO feasibility frontier (fraction of the (B, r, scheduler) grid that
meets the committed p99 targets, plus the cost of the cheapest feasible
Pareto candidate).  Rows are labelled by the run id carried in the artifact path
(``gh run download`` lands each artifact in its own directory) and sorted
naturally, so the table reads chronologically.

``--svg PATH`` additionally renders the same series as one self-contained
SVG of per-series sparklines (pure stdlib, no plotting deps) -- the at-a-
glance trend picture the markdown table can't give; the nightly workflow
uploads it next to the JSON artifact.

Usage::

    python benchmarks/nightly_trend.py artifacts_dir_or_json [more ...]
    python benchmarks/nightly_trend.py bench-history fresh.json \\
        --svg trend.svg >> "$GITHUB_STEP_SUMMARY"

The nightly workflow downloads the retained artifact series into
``bench-history/run-<id>/`` and points this script at the directory plus the
fresh run's JSON.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import re
import sys


def _natkey(label: str) -> tuple:
    """Natural sort key: digit runs compare numerically (run-9 < run-10)."""
    return tuple(
        int(chunk) if chunk.isdigit() else chunk
        for chunk in re.split(r"(\d+)", label)
    )


def _label(root: pathlib.Path, f: pathlib.Path) -> str:
    """Row label for one bench JSON: the most specific path component that
    carries a run id (a digit sequence), falling back to the stem.  Keeps
    downloaded-artifact layouts (``run-<id>/<artifact>/bench.json``, where
    every stem is identical) distinguishable in the table."""
    parts = (f.relative_to(root).parts if root.is_dir() else ()) + (f.stem,)
    for part in parts:
        if any(c.isdigit() for c in part):
            return part.removesuffix(".json")
    return f.stem


def _load(paths: list[pathlib.Path]) -> list[tuple[str, dict]]:
    rows = []
    for p in paths:
        candidates = sorted(p.glob("**/*.json")) if p.is_dir() else [p]
        for f in candidates:
            try:
                rows.append((_label(p, f), json.loads(f.read_text())))
            except (OSError, json.JSONDecodeError) as ex:
                print(f"skipping {f}: {ex}", file=sys.stderr)
    # run-id labels sort naturally; a digit-less label is the freshly
    # produced run (tonight's JSON has no run id yet -- the artifact name
    # gains one only on upload) and belongs at the bottom, newest last
    rows.sort(key=lambda r: (0 if any(c.isdigit() for c in r[0]) else 1, _natkey(r[0])))
    return rows


def _get(d: dict, *keys, default=None):
    for k in keys:
        if not isinstance(d, dict) or k not in d:
            return default
        d = d[k]
    return d


def trend_table(rows: list[tuple[str, dict]]) -> str:
    """Markdown table over the load-bearing nightly series."""
    header = (
        "| run | static edge (min..max) | dynamic edge (min..max) "
        "| space edge (min..max) | packed/gang resp | dynamic cold (s) "
        "| peak RSS (MB) | trace warm (s) | trace RSS (MB) "
        "| heavy-tail speedup | spec pareto (react/hybrid) "
        "| slo feasible | slo pareto cost (w-s) |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|"
    )
    lines = [header]
    for name, d in rows:
        b = _get(d, "backend") or {}
        dy = _get(d, "dynamic") or {}
        sp = _get(d, "space_sharing") or {}
        sk = _get(d, "speculation") or {}
        tr = _get(d, "trace_scale") or {}
        sl = _get(d, "slo") or {}
        heavy = _get(d, "redundancy", "_summary", "max_heavy_speedup")

        def fmt(v, spec=".1f", suffix=""):
            return format(v, spec) + suffix if isinstance(v, (int, float)) else "-"

        lines.append(
            "| {} | {}..{} | {}..{} | {}..{} | {} | {} | {} | {} | {} | {} | {}/{} | {} | {} |".format(
                name,
                fmt(b.get("min_speedup_warm"), ".0f", "x"),
                fmt(b.get("max_speedup_warm"), ".0f", "x"),
                fmt(dy.get("min_speedup_warm"), ".0f", "x"),
                fmt(dy.get("max_speedup_warm"), ".0f", "x"),
                fmt(sp.get("min_speedup_warm"), ".0f", "x"),
                fmt(sp.get("max_speedup_warm"), ".0f", "x"),
                fmt(sp.get("response_ratio_packed_vs_gang"), ".2f", "x"),
                fmt(dy.get("max_cold_seconds"), ".2f"),
                fmt(dy.get("peak_rss_mb"), ".0f"),
                fmt(tr.get("sweep_seconds_warm"), ".2f"),
                fmt(tr.get("peak_rss_mb"), ".0f"),
                fmt(heavy, ".2f", "x"),
                fmt(sk.get("pareto_speculative_speedup"), ".2f", "x"),
                fmt(sk.get("pareto_hybrid_speedup"), ".2f", "x"),
                fmt(sl.get("feasible_frac"), ".0%"),
                fmt(_get(sl, "pareto_heavy", "best", "cost_worker_seconds"), ".0f"),
            )
        )
    return "\n".join(lines)


# the sparkline series: one row per load-bearing scalar, addressed by its
# JSON path into a bench artifact (shared vocabulary with trend_table)
_SERIES = [
    ("static edge (min)", ("backend", "min_speedup_warm")),
    ("dynamic edge (min)", ("dynamic", "min_speedup_warm")),
    ("space edge (min)", ("space_sharing", "min_speedup_warm")),
    ("packed/gang response", ("space_sharing", "response_ratio_packed_vs_gang")),
    ("dynamic cold (s)", ("dynamic", "max_cold_seconds")),
    ("trace sweep warm (s)", ("trace_scale", "sweep_seconds_warm")),
    ("trace peak RSS (MB)", ("trace_scale", "peak_rss_mb")),
    ("heavy-tail speedup", ("redundancy", "_summary", "max_heavy_speedup")),
    ("spec pareto (react)", ("speculation", "pareto_speculative_speedup")),
    ("spec pareto (hybrid)", ("speculation", "pareto_hybrid_speedup")),
    ("slo feasible frac", ("slo", "feasible_frac")),
    ("slo pareto cost (w-s)", ("slo", "pareto_heavy", "best", "cost_worker_seconds")),
    ("slo sweep warm (s)", ("slo", "sweep_seconds_warm")),
]


def sparkline_svg(rows: list[tuple[str, dict]]) -> str:
    """One self-contained SVG: a labelled sparkline per load-bearing series.

    Runs missing a section simply contribute no point (old artifacts predate
    newer bench sections); a series with one point renders as a dot, and the
    latest value is printed at the right edge.  Stdlib-only on purpose --
    the nightly runner has no plotting stack.
    """
    label_w, plot_w, row_h, pad = 170, 240, 26, 5
    n = len(rows)
    parts = []
    for si, (label, keys) in enumerate(_SERIES):
        pts = []
        for i, (_, d) in enumerate(rows):
            v = _get(d, *keys)
            if isinstance(v, (int, float)):
                pts.append((i, float(v)))
        y0 = si * row_h
        parts.append(
            f'<text x="2" y="{y0 + row_h - 9}" font-size="10" '
            f'font-family="monospace">{label}</text>'
        )
        if not pts:
            continue
        lo = min(v for _, v in pts)
        span = max(v for _, v in pts) - lo or 1.0

        def xy(i, v, y0=y0, lo=lo, span=span):
            x = label_w + pad + (plot_w - 2 * pad) * (i / max(n - 1, 1))
            y = y0 + pad + (row_h - 2 * pad) * (1.0 - (v - lo) / span)
            return f"{x:.1f},{y:.1f}"

        coords = [xy(i, v) for i, v in pts]
        if len(coords) > 1:
            parts.append(
                '<polyline fill="none" stroke="#2b6cb0" stroke-width="1.5" '
                f'points="{" ".join(coords)}"/>'
            )
        cx, cy = coords[-1].split(",")
        parts.append(f'<circle cx="{cx}" cy="{cy}" r="2" fill="#2b6cb0"/>')
        parts.append(
            f'<text x="{label_w + plot_w + 4}" y="{y0 + row_h - 9}" font-size="10" '
            f'font-family="monospace">{pts[-1][1]:.2f}</text>'
        )
    w, h = label_w + plot_w + 60, len(_SERIES) * row_h
    return (
        f'<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}" '
        f'viewBox="0 0 {w} {h}">' + "".join(parts) + "</svg>"
    )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="+", type=pathlib.Path, help="bench JSONs or dirs")
    ap.add_argument(
        "--svg",
        type=pathlib.Path,
        default=None,
        help="also render the series as one sparkline SVG at this path",
    )
    args = ap.parse_args()
    rows = _load(args.paths)
    if not rows:
        print("no bench JSONs found", file=sys.stderr)
        return 1
    print("### cluster bench trend\n")
    print(trend_table(rows))
    if args.svg is not None:
        args.svg.parent.mkdir(parents=True, exist_ok=True)
        args.svg.write_text(sparkline_svg(rows))
        print(f"wrote {args.svg}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
