"""Kernel + train-step microbenchmarks (CPU wall clock).

NOTE: Pallas kernels run in interpret mode on CPU -- the timings validate
plumbing and give a *relative* CPU baseline; TPU performance is modeled by
the roofline (the kernel's BlockSpec tiling is sized for v5e VMEM/MXU).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import PipelineConfig, SyntheticLM
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ref import flash_attention_ref
from repro.kernels.rmsnorm import rms_norm_fused
from repro.models import build_model
from repro.optim import AdamW
from repro.runtime.train import init_state, make_train_step


def _time(fn, *args, n=5):
    jax.block_until_ready(fn(*args))  # works on pytrees, tuples included
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / n * 1e6  # us


def bench_flash_kernel():
    b, h, s, hd = 1, 4, 512, 64
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, h, s, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, h, s, hd), jnp.float32)

    pallas_us = _time(lambda a, b2, c: flash_attention_fwd(a, b2, c, interpret=True), q, k, v)
    ref_us = _time(lambda a, b2, c: flash_attention_ref(a, b2, c), q, k, v)
    err = float(jnp.abs(
        flash_attention_fwd(q, k, v, interpret=True) - flash_attention_ref(q, k, v)
    ).max())
    return [
        ("flash_kernel_interp_512", pallas_us, f"maxerr={err:.1e}"),
        ("flash_ref_jnp_512", ref_us, "oracle"),
    ]


def bench_rmsnorm_kernel():
    x = jax.random.normal(jax.random.key(0), (512, 1024), jnp.float32)
    w = jnp.ones((1024,))
    us = _time(lambda a: rms_norm_fused(a, w, interpret=True), x)
    return [("rmsnorm_kernel_interp", us, "fused 1-pass")]


def bench_train_step_tiny():
    """Tokens/s of the full jitted train step on a tiny dense model (CPU)."""
    cfg = get_config("qwen2-1.5b", smoke=True)
    model = build_model(cfg)
    opt = AdamW(learning_rate=1e-3)
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    pipe = SyntheticLM(PipelineConfig(cfg.vocab_size, 64, 8))
    state = init_state(model, opt, jax.random.key(0))
    batch = {k: jnp.asarray(v) for k, v in pipe.global_batch(0).items()}
    state, _ = step(state, batch)  # compile
    t0 = time.time()
    n = 10
    for i in range(n):
        state, m = step(state, batch)
    jax.block_until_ready(m["loss"])
    dt = (time.time() - t0) / n
    toks = 8 * 64 / dt
    return [("train_step_tiny", dt * 1e6, f"{toks:.0f} tokens/s CPU")]


def run_all():
    rows = []
    rows.extend(bench_flash_kernel())
    rows.extend(bench_rmsnorm_kernel())
    rows.extend(bench_train_step_tiny())
    return rows
