"""§VII trace-driven experiments (Figs. 11-13 stand-in).

Runs the paper's empirical methodology on the synthetic Google-trace-like
jobs: classify tails (Fig 11), compute the normalized E[T] vs B curve per
job with the size-dependent bootstrap (Figs 12-13), and verify the headline
claim -- planned redundancy speeds heavy-tail jobs up by about an order of
magnitude relative to no redundancy.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from repro.core import traces
from repro.core.planner import RedundancyPlanner

ART = pathlib.Path(__file__).resolve().parent / "artifacts" / "paper"
N_WORKERS = 100


def bench_fig11_tails():
    t0 = time.time()
    jobs = traces.synthetic_google_jobs()
    fams = {j.name: traces.tail_family(j.task_times) for j in jobs}
    agree = sum(fams[j.name] == j.family for j in jobs)
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "fig11_tails.json").write_text(json.dumps(
        {j.name: {"generator": j.family, "classified": fams[j.name],
                  "n_tasks": j.n_tasks} for j in jobs}, indent=2))
    us = (time.time() - t0) * 1e6 / len(jobs)
    return [("fig11_tails", us, f"classifier agrees {agree}/10 jobs")]


def bench_fig12_13_redundancy(n_mc: int = 8000):
    t0 = time.time()
    jobs = traces.synthetic_google_jobs()
    planner = RedundancyPlanner(N_WORKERS)
    curves = {}
    speedups = {}
    for j in jobs:
        plan = planner.plan_empirical(j.task_times, "mean", n_mc=n_mc, seed=1)
        means = np.asarray(plan.frontier_mean)
        base = means[plan.frontier_B.index(N_WORKERS)]  # B=N: no redundancy
        curves[j.name] = {
            "family": j.family,
            "B": list(plan.frontier_B),
            "ET_norm": (means / base).tolist(),
            "B_star": plan.n_batches,
        }
        speedups[j.name] = float(base / means.min())
    (ART / "fig12_13_redundancy.json").write_text(json.dumps(curves, indent=2))
    heavy = [speedups[j.name] for j in jobs if j.family == "heavy"]
    expo = [speedups[j.name] for j in jobs if j.family == "exponential"]
    us = (time.time() - t0) * 1e6 / len(jobs)
    return [(
        "fig12_13_redundancy", us,
        f"max speedup heavy={max(heavy):.1f}x exp={max(expo):.2f}x; "
        f"heavy jobs gain >= {min(heavy):.1f}x",
    )]


def run_all():
    rows = []
    rows.extend(bench_fig11_tails())
    rows.extend(bench_fig12_13_redundancy())
    return rows
