"""Bench-regression gate: compare a smoke-bench JSON against the baseline.

CI runs ``cluster_bench.py --smoke`` on every PR and then gates the result
against the committed ``BENCH_cluster.json`` (same smoke config, same seed,
so the Monte-Carlo sections replay near-identically; only wall-clock numbers
vary with the runner).  Two properties are load-bearing and fail the build:

  1. the vectorized jax backend keeps its wall-clock edge over the Python
     event engine on a full-frontier ``plan_cluster`` sweep
     (``backend.min_speedup_warm`` stays above an absolute floor -- machine
     speeds vary, ratios of times on the same machine much less),
  2. planned redundancy keeps its heavy-tail speedup
     (``redundancy._summary.max_heavy_speedup`` does not regress beyond a
     fractional tolerance of the baseline),
  3. the epoch-scan step loop keeps its edge on the *churned/heterogeneous*
     sweep (``dynamic.min_speedup_warm`` above its own floor -- this is the
     sweep that used to fall back to the Python engine entirely, and the
     de-serialized step loop raised its floor from 3x to 25x), and
  4. the dynamic path's cold start stays interactive
     (``dynamic.dists.*.jax_seconds_cold``, first-call compile+run, below an
     absolute ceiling -- compile-time regressions hide behind warm timings),
  5. space sharing keeps paying off and keeps its backend edge
     (``space_sharing.response_ratio_packed_vs_gang`` stays below a ceiling
     -- packed concurrent narrow jobs must beat the serial gang -- and
     ``space_sharing.min_speedup_warm`` stays above its own floor), and
  6. reactive speculation keeps beating the no-redundancy baseline on the
     heavy Pareto tail (``speculation.pareto_speculative_speedup`` above an
     absolute floor -- backups launched from partial progress must keep
     truncating the straggler tail), and
  7. the trace-scale stream path keeps cluster-day throughput *and* its
     O(slab) memory story (``trace_scale.sweep_seconds_warm`` -- the full
     (family x budget x scheduler) grid over the synthetic cluster-day,
     warm -- stays below an absolute ceiling, and ``trace_scale.peak_rss_mb``
     stays below the committed RSS ceiling; a path that re-materializes
     per-job outputs blows through both), and
  8. the tail-SLO planner keeps the paper's headline trade-off alive
     (``slo.all_feasible`` -- every parametric tail family still has a
     feasible (B, r, scheduler) candidate at the committed targets -- and
     ``slo.pareto_mean_vs_tail_diverge`` -- on the heavy Pareto family the
     mean-optimal candidate must keep differing from the cheapest
     p99-feasible one; losing either means the planner or the streaming
     quantile state silently broke -- and ``slo.sweep_seconds_warm`` stays
     below an absolute ceiling), and
  9. master crash-recovery stays cheap on the live runtime (``--runtime``
     takes ``runtime_bench.py``'s JSON and gates
     ``recovery.recovery_overhead`` -- the crashed-and-journal-recovered
     makespan over the uninterrupted one -- below a ceiling, and requires
     the recovered journal to have replayed exactly through the engine).

Floors are env-overridable so a one-off noisy runner can be diagnosed
without editing the workflow:

  BENCH_MIN_JAX_SPEEDUP          absolute floor on backend.min_speedup_warm (10)
  BENCH_HEAVY_TOLERANCE          fraction of baseline heavy speedup to keep (0.5)
  BENCH_MIN_JAX_DYNAMIC_SPEEDUP  absolute floor on dynamic.min_speedup_warm (25)
  BENCH_MAX_JAX_DYNAMIC_COLD_SECONDS  ceiling on dynamic cold seconds (4.0)
  BENCH_MIN_JAX_SPACE_SPEEDUP    absolute floor on space_sharing.min_speedup_warm (8)
  BENCH_MAX_SPACE_RESPONSE_RATIO ceiling on packed/gang response ratio (0.85)
  BENCH_MIN_SPEC_SPEEDUP         floor on speculation.pareto_speculative_speedup (1.1)
  BENCH_MAX_TRACE_SWEEP_SECONDS  ceiling on trace_scale.sweep_seconds_warm (9.0)
  BENCH_MAX_TRACE_PEAK_RSS_MB    ceiling on trace_scale.peak_rss_mb (2048)
  BENCH_MAX_SLO_SWEEP_SECONDS    ceiling on slo.sweep_seconds_warm (5.0)
  BENCH_MAX_RECOVERY_OVERHEAD    ceiling on recovery.recovery_overhead (3.0)
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

DEFAULT_MIN_JAX_SPEEDUP = 10.0
DEFAULT_HEAVY_TOLERANCE = 0.5
DEFAULT_MIN_JAX_DYNAMIC_SPEEDUP = 25.0
DEFAULT_MAX_JAX_DYNAMIC_COLD_SECONDS = 4.0
DEFAULT_MIN_JAX_SPACE_SPEEDUP = 8.0
DEFAULT_MAX_SPACE_RESPONSE_RATIO = 0.85
DEFAULT_MIN_SPEC_SPEEDUP = 1.1
DEFAULT_MAX_TRACE_SWEEP_SECONDS = 9.0
DEFAULT_MAX_TRACE_PEAK_RSS_MB = 2048.0
DEFAULT_MAX_SLO_SWEEP_SECONDS = 5.0
DEFAULT_MAX_RECOVERY_OVERHEAD = 3.0


def check_runtime(runtime: dict, max_recovery_overhead: float) -> list:
    """Gate the live-runtime bench JSON (``runtime_bench.py`` output): master
    crash-recovery must stay cheap and the recovered journal must have
    replayed exactly.  Returns human-readable failure strings."""
    failures = []
    rec = runtime.get("recovery", {})
    if not rec:
        failures.append("recovery section missing from runtime bench JSON")
        return failures
    if not rec.get("crash_exercised"):
        failures.append(
            "recovery bench never crashed the master: the workload finished "
            "before the crash timer, so the recovery path went unmeasured"
        )
    if not rec.get("twin_replay_exact"):
        failures.append("engine replay of the crashed-and-recovered journal is not exact")
    overhead = rec.get("recovery_overhead")
    if overhead is None or overhead > max_recovery_overhead:
        failures.append(
            f"master crash-recovery got expensive: recovery_overhead "
            f"{overhead if overhead is None else format(overhead, '.2f')}x "
            f"> ceiling {max_recovery_overhead:.2f}x "
            f"(recovered makespan {rec.get('recovered_makespan_s', float('nan'))}s "
            f"vs plain {rec.get('plain_makespan_s', float('nan'))}s)"
        )
    return failures


def check(
    current: dict,
    baseline: dict,
    min_jax_speedup: float,
    heavy_tolerance: float,
    min_jax_dynamic_speedup: float = DEFAULT_MIN_JAX_DYNAMIC_SPEEDUP,
    max_jax_dynamic_cold_seconds: float = DEFAULT_MAX_JAX_DYNAMIC_COLD_SECONDS,
    min_jax_space_speedup: float = DEFAULT_MIN_JAX_SPACE_SPEEDUP,
    max_space_response_ratio: float = DEFAULT_MAX_SPACE_RESPONSE_RATIO,
    min_spec_speedup: float = DEFAULT_MIN_SPEC_SPEEDUP,
    max_trace_sweep_seconds: float = DEFAULT_MAX_TRACE_SWEEP_SECONDS,
    max_trace_peak_rss_mb: float = DEFAULT_MAX_TRACE_PEAK_RSS_MB,
    max_slo_sweep_seconds: float = DEFAULT_MAX_SLO_SWEEP_SECONDS,
) -> list:
    """Return a list of human-readable failure strings (empty = gate passes)."""
    failures = []

    cur_edge = current["backend"]["min_speedup_warm"]
    base_edge = baseline["backend"]["min_speedup_warm"]
    if cur_edge < min_jax_speedup:
        failures.append(
            f"jax backend lost its speed edge: min_speedup_warm {cur_edge:.1f}x "
            f"< floor {min_jax_speedup:.1f}x (baseline recorded {base_edge:.1f}x)"
        )

    cur_heavy = current["redundancy"]["_summary"]["max_heavy_speedup"]
    base_heavy = baseline["redundancy"]["_summary"]["max_heavy_speedup"]
    if cur_heavy is None or base_heavy is None:
        failures.append("heavy-tail speedup missing from current or baseline redundancy summary")
    elif cur_heavy < heavy_tolerance * base_heavy:
        failures.append(
            f"heavy-tail redundancy speedup regressed: {cur_heavy:.2f}x "
            f"< {heavy_tolerance:.2f} * baseline {base_heavy:.2f}x"
        )

    cur_dyn = current.get("dynamic", {}).get("min_speedup_warm")
    base_dyn = baseline.get("dynamic", {}).get("min_speedup_warm")
    if cur_dyn is None or base_dyn is None:
        failures.append("dynamic (churned/hetero) sweep section missing from current or baseline")
    elif cur_dyn < min_jax_dynamic_speedup:
        failures.append(
            f"jax epoch scan lost its churned-sweep edge: dynamic.min_speedup_warm "
            f"{cur_dyn:.1f}x < floor {min_jax_dynamic_speedup:.1f}x "
            f"(baseline recorded {base_dyn:.1f}x)"
        )

    cold = [
        d.get("jax_seconds_cold")
        for d in current.get("dynamic", {}).get("dists", {}).values()
    ]
    cold = [c for c in cold if c is not None]
    if cold and max(cold) > max_jax_dynamic_cold_seconds:
        failures.append(
            f"dynamic cold start regressed: {max(cold):.2f}s "
            f"> ceiling {max_jax_dynamic_cold_seconds:.2f}s "
            f"(compile-time regressions hide behind warm timings)"
        )

    cur_sp = current.get("space_sharing", {})
    base_sp = baseline.get("space_sharing", {})
    if not cur_sp or not base_sp:
        failures.append("space_sharing section missing from current or baseline")
    else:
        ratio = cur_sp.get("response_ratio_packed_vs_gang")
        if ratio is None or ratio > max_space_response_ratio:
            failures.append(
                f"space sharing stopped paying off: packed/gang response ratio "
                f"{ratio if ratio is None else format(ratio, '.2f')} "
                f"> ceiling {max_space_response_ratio:.2f} "
                f"(baseline recorded "
                f"{base_sp.get('response_ratio_packed_vs_gang', float('nan')):.2f})"
            )
        sp_edge = cur_sp.get("min_speedup_warm")
        if sp_edge is None or sp_edge < min_jax_space_speedup:
            failures.append(
                f"jax space lane lost its edge: space_sharing.min_speedup_warm "
                f"{sp_edge if sp_edge is None else format(sp_edge, '.1f')}x "
                f"< floor {min_jax_space_speedup:.1f}x "
                f"(baseline recorded {base_sp.get('min_speedup_warm', float('nan')):.1f}x)"
            )

    cur_sk = current.get("speculation", {})
    base_sk = baseline.get("speculation", {})
    if not cur_sk or not base_sk:
        failures.append("speculation section missing from current or baseline")
    else:
        sk = cur_sk.get("pareto_speculative_speedup")
        if sk is None or sk < min_spec_speedup:
            failures.append(
                f"speculation stopped paying off on the heavy tail: "
                f"pareto_speculative_speedup "
                f"{sk if sk is None else format(sk, '.2f')}x "
                f"< floor {min_spec_speedup:.2f}x (baseline recorded "
                f"{base_sk.get('pareto_speculative_speedup', float('nan')):.2f}x)"
            )

    cur_tr = current.get("trace_scale", {})
    base_tr = baseline.get("trace_scale", {})
    if not cur_tr or not base_tr:
        failures.append("trace_scale section missing from current or baseline")
    else:
        warm = cur_tr.get("sweep_seconds_warm")
        if warm is None or warm > max_trace_sweep_seconds:
            failures.append(
                f"trace-scale sweep slowed down: sweep_seconds_warm "
                f"{warm if warm is None else format(warm, '.2f')}s "
                f"> ceiling {max_trace_sweep_seconds:.2f}s (baseline recorded "
                f"{base_tr.get('sweep_seconds_warm', float('nan')):.2f}s)"
            )
        rss = cur_tr.get("peak_rss_mb")
        if rss is None or rss > max_trace_peak_rss_mb:
            failures.append(
                f"trace-scale memory story broke: peak_rss_mb "
                f"{rss if rss is None else format(rss, '.0f')} MB "
                f"> ceiling {max_trace_peak_rss_mb:.0f} MB (baseline recorded "
                f"{base_tr.get('peak_rss_mb', float('nan')):.0f} MB) -- "
                f"the stream path must stay O(slab), not O(jobs)"
            )

    cur_sl = current.get("slo", {})
    base_sl = baseline.get("slo", {})
    if not cur_sl or not base_sl:
        failures.append("slo section missing from current or baseline")
    else:
        if not cur_sl.get("all_feasible"):
            infeasible = [
                name
                for name, v in cur_sl.items()
                if isinstance(v, dict) and not v.get("feasible", True)
            ]
            failures.append(
                f"tail-SLO planner lost feasibility: no (B, r, scheduler) "
                f"candidate meets the committed p99 targets for "
                f"{infeasible or 'unknown families'} (baseline had all "
                f"families feasible)"
            )
        if not cur_sl.get("pareto_mean_vs_tail_diverge"):
            failures.append(
                "tail-SLO planner stopped reproducing the mean-optimal != "
                "tail-optimal trade-off on the heavy Pareto family: the "
                "cheapest p99-feasible candidate now coincides with the "
                "mean-optimal one (baseline kept them distinct)"
            )
        sl_warm = cur_sl.get("sweep_seconds_warm")
        if sl_warm is None or sl_warm > max_slo_sweep_seconds:
            failures.append(
                f"tail-SLO grid sweep slowed down: slo.sweep_seconds_warm "
                f"{sl_warm if sl_warm is None else format(sl_warm, '.2f')}s "
                f"> ceiling {max_slo_sweep_seconds:.2f}s (baseline recorded "
                f"{base_sl.get('sweep_seconds_warm', float('nan')):.2f}s)"
            )

    return failures


def _fmt(v) -> str:
    return f"{v:.2f}x" if v is not None else "missing"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", type=pathlib.Path, help="freshly produced smoke-bench JSON")
    ap.add_argument("baseline", type=pathlib.Path, help="committed BENCH_cluster.json baseline")
    ap.add_argument(
        "--runtime",
        type=pathlib.Path,
        default=None,
        help="runtime_bench.py smoke JSON: gates recovery overhead and replay exactness",
    )
    args = ap.parse_args()

    current = json.loads(args.current.read_text())
    baseline = json.loads(args.baseline.read_text())
    min_jax_speedup = float(os.environ.get("BENCH_MIN_JAX_SPEEDUP", DEFAULT_MIN_JAX_SPEEDUP))
    heavy_tolerance = float(os.environ.get("BENCH_HEAVY_TOLERANCE", DEFAULT_HEAVY_TOLERANCE))
    min_jax_dynamic = float(
        os.environ.get("BENCH_MIN_JAX_DYNAMIC_SPEEDUP", DEFAULT_MIN_JAX_DYNAMIC_SPEEDUP)
    )
    max_dynamic_cold = float(
        os.environ.get(
            "BENCH_MAX_JAX_DYNAMIC_COLD_SECONDS", DEFAULT_MAX_JAX_DYNAMIC_COLD_SECONDS
        )
    )
    min_jax_space = float(
        os.environ.get("BENCH_MIN_JAX_SPACE_SPEEDUP", DEFAULT_MIN_JAX_SPACE_SPEEDUP)
    )
    max_space_ratio = float(
        os.environ.get("BENCH_MAX_SPACE_RESPONSE_RATIO", DEFAULT_MAX_SPACE_RESPONSE_RATIO)
    )
    min_spec = float(os.environ.get("BENCH_MIN_SPEC_SPEEDUP", DEFAULT_MIN_SPEC_SPEEDUP))
    max_trace_sweep = float(
        os.environ.get("BENCH_MAX_TRACE_SWEEP_SECONDS", DEFAULT_MAX_TRACE_SWEEP_SECONDS)
    )
    max_trace_rss = float(
        os.environ.get("BENCH_MAX_TRACE_PEAK_RSS_MB", DEFAULT_MAX_TRACE_PEAK_RSS_MB)
    )
    max_slo_sweep = float(
        os.environ.get("BENCH_MAX_SLO_SWEEP_SECONDS", DEFAULT_MAX_SLO_SWEEP_SECONDS)
    )

    max_recovery = float(
        os.environ.get("BENCH_MAX_RECOVERY_OVERHEAD", DEFAULT_MAX_RECOVERY_OVERHEAD)
    )

    failures = check(
        current, baseline, min_jax_speedup, heavy_tolerance, min_jax_dynamic,
        max_dynamic_cold, min_jax_space, max_space_ratio, min_spec,
        max_trace_sweep, max_trace_rss, max_slo_sweep,
    )
    runtime = json.loads(args.runtime.read_text()) if args.runtime else None
    if runtime is not None:
        failures += check_runtime(runtime, max_recovery)

    cur_b, base_b = current["backend"], baseline["backend"]
    print(
        f"jax frontier sweep edge: {cur_b['min_speedup_warm']:.1f}x"
        f"..{cur_b['max_speedup_warm']:.1f}x "
        f"(baseline {base_b['min_speedup_warm']:.1f}x..{base_b['max_speedup_warm']:.1f}x, "
        f"floor {min_jax_speedup:.1f}x)"
    )
    cur_heavy = current["redundancy"]["_summary"]["max_heavy_speedup"]
    base_heavy = baseline["redundancy"]["_summary"]["max_heavy_speedup"]
    print(
        f"heavy-tail redundancy speedup: {_fmt(cur_heavy)} "
        f"(baseline {_fmt(base_heavy)}, tolerance {heavy_tolerance:.2f})"
    )
    cur_d = current.get("dynamic", {})
    base_d = baseline.get("dynamic", {})
    if cur_d and base_d:
        print(
            f"jax churned/hetero sweep edge: {cur_d['min_speedup_warm']:.1f}x"
            f"..{cur_d['max_speedup_warm']:.1f}x "
            f"(baseline {base_d['min_speedup_warm']:.1f}x"
            f"..{base_d['max_speedup_warm']:.1f}x, floor {min_jax_dynamic:.1f}x)"
        )
        cold = [
            d.get("jax_seconds_cold") for d in cur_d.get("dists", {}).values()
        ]
        cold = [c for c in cold if c is not None]
        if cold:
            print(
                f"dynamic cold start: {max(cold):.2f}s "
                f"(ceiling {max_dynamic_cold:.2f}s); "
                f"peak RSS {cur_d.get('peak_rss_mb', float('nan')):.0f} MB"
            )

    cur_sp = current.get("space_sharing", {})
    base_sp = baseline.get("space_sharing", {})
    if cur_sp and base_sp:
        print(
            f"space sharing: packed/gang response "
            f"x{cur_sp.get('response_ratio_packed_vs_gang', float('nan')):.2f} "
            f"(baseline x{base_sp.get('response_ratio_packed_vs_gang', float('nan')):.2f}, "
            f"ceiling {max_space_ratio:.2f}); "
            f"jax space sweep edge {cur_sp.get('min_speedup_warm', float('nan')):.1f}x"
            f"..{cur_sp.get('max_speedup_warm', float('nan')):.1f}x "
            f"(floor {min_jax_space:.1f}x)"
        )

    cur_sk = current.get("speculation", {})
    base_sk = baseline.get("speculation", {})
    if cur_sk and base_sk:
        print(
            f"speculation on heavy Pareto: speculative "
            f"x{cur_sk.get('pareto_speculative_speedup', float('nan')):.2f}, "
            f"hybrid x{cur_sk.get('pareto_hybrid_speedup', float('nan')):.2f} "
            f"vs no redundancy (baseline "
            f"x{base_sk.get('pareto_speculative_speedup', float('nan')):.2f}, "
            f"floor {min_spec:.2f}x)"
        )

    cur_tr = current.get("trace_scale", {})
    base_tr = baseline.get("trace_scale", {})
    if cur_tr and base_tr:
        print(
            f"trace-scale cluster-day: {cur_tr.get('n_cells', 0)}-cell sweep "
            f"{cur_tr.get('sweep_seconds_warm', float('nan')):.2f}s warm "
            f"(baseline {base_tr.get('sweep_seconds_warm', float('nan')):.2f}s, "
            f"ceiling {max_trace_sweep:.1f}s); peak RSS "
            f"{cur_tr.get('peak_rss_mb', float('nan')):.0f} MB "
            f"(ceiling {max_trace_rss:.0f} MB)"
        )

    cur_sl = current.get("slo", {})
    base_sl = baseline.get("slo", {})
    if cur_sl and base_sl:
        best = (cur_sl.get("pareto_heavy") or {}).get("best") or {}
        mean_opt = (cur_sl.get("pareto_heavy") or {}).get("mean_optimal") or {}
        print(
            f"tail-SLO planner: feasible on {cur_sl.get('feasible_frac', 0):.0%} "
            f"of the grid, all families feasible: {cur_sl.get('all_feasible')}; "
            f"pareto best (sched={best.get('scheduler')}, "
            f"w={best.get('workers_per_job')}, B={best.get('B')}, "
            f"r={best.get('r')}) vs mean-opt (sched={mean_opt.get('scheduler')}, "
            f"w={mean_opt.get('workers_per_job')}, B={mean_opt.get('B')}, "
            f"r={mean_opt.get('r')}); sweep "
            f"{cur_sl.get('sweep_seconds_warm', float('nan')):.2f}s warm "
            f"(ceiling {max_slo_sweep:.1f}s)"
        )

    if runtime is not None:
        rec = runtime.get("recovery", {})
        if rec:
            print(
                f"runtime crash-recovery: makespan overhead "
                f"x{rec.get('recovery_overhead', float('nan')):.2f} "
                f"(ceiling {max_recovery:.2f}x); recovered journal replay "
                f"{'exact' if rec.get('twin_replay_exact') else 'NOT EXACT'}; "
                f"{rec.get('n_journal_events', 0)} journal events"
            )

    if failures:
        for f in failures:
            print(f"REGRESSION: {f}", file=sys.stderr)
        return 1
    print("bench regression gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
