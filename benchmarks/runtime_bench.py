"""Live-runtime benchmark: real execution time vs the engine's prediction.

Runs a small redundant workload on the live asyncio master-worker runtime
(``repro.cluster.runtime``: real localhost sockets, thread workers, sleep
payloads) and compares three layers:

  * ``live``      -- wall-clock makespan and accounting measured by the
    master from its own grid-stamped trace;
  * ``replay``    -- the same trace replayed through the discrete-event
    engine (the digital twin): must match the live accounting *exactly*,
    so its row is a correctness canary, not an estimate;
  * ``predicted`` -- an a-priori ``ClusterEngine`` run with deterministic
    service times equal to the nominal batch costs: what the simulator
    promised before any real process ran.

``live_over_predicted`` is the headline ratio: how much real-world overhead
(socket round trips, event-loop scheduling, sleep granularity) inflates the
simulated makespan.  ``--smoke`` keeps the workload at a few hundred
milliseconds for CI, which uploads the JSON as an artifact; a ratio above
``--max-ratio`` (sanity, generous) fails the run.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.cluster.master import ClusterEngine, Job  # noqa: E402
from repro.cluster.runtime import LiveJob, Runtime, replay_trace  # noqa: E402
from repro.cluster.scenario import Scenario  # noqa: E402

ART = pathlib.Path(__file__).resolve().parent / "artifacts"


@dataclasses.dataclass
class _Deterministic:
    """Constant service time: the engine's a-priori model of a known cost."""

    value: float

    def sample_np(self, rng, shape):
        return self.value


def _workload(cfg: dict):
    """Uniform per-task costs so every batch of a job has one nominal cost
    (what the deterministic predictor needs), three jobs back to back."""
    n, b = cfg["n_workers"], cfg["n_batches"]
    jobs = [
        LiveJob(
            job_id=i,
            costs=(cfg["task_cost"],) * cfg["n_tasks"],
            skew=cfg["skew"],
            name=f"bench-{i}",
        )
        for i in range(cfg["n_jobs"])
    ]
    scenario = Scenario(n_batches=b, cancel_redundant=True)
    batch_cost = cfg["task_cost"] * (cfg["n_tasks"] // b)
    predicted = [
        Job(job_id=j.job_id, dist=_Deterministic(batch_cost), n_tasks=cfg["n_tasks"])
        for j in jobs
    ]
    return n, scenario, jobs, predicted


def bench_runtime(cfg: dict) -> dict:
    n, scenario, jobs, predicted_jobs = _workload(cfg)

    t0 = time.monotonic()
    report = Runtime(n, scenario).run(jobs, timeout_s=120.0)
    live_wall = time.monotonic() - t0

    live_makespan = max(r.finish for r in report.records)
    twin = replay_trace(report.trace, n, scenario)
    twin_exact = twin.accounting() == report.accounting()

    eng = ClusterEngine(
        n,
        seed=0,
        n_batches=scenario.n_batches,
        cancel_redundant=True,
        size_dependent=False,
    ).run(predicted_jobs)
    predicted_makespan = max(r.finish for r in eng.records)

    return {
        "n_workers": n,
        "n_jobs": len(jobs),
        "n_batches": scenario.n_batches,
        "replication": report.records[0].replication,
        "live_wall_s": round(live_wall, 4),
        "live_makespan_s": round(live_makespan, 4),
        "predicted_makespan_s": round(predicted_makespan, 4),
        "live_over_predicted": round(live_makespan / predicted_makespan, 4),
        "live_accounting": report.accounting(),
        "predicted_accounting": eng.accounting(),
        "twin_replay_exact": twin_exact,
        "n_trace_events": len(report.trace),
    }


def _cfg(smoke: bool) -> dict:
    if smoke:
        return {
            "n_workers": 4,
            "n_batches": 2,
            "n_tasks": 4,
            "n_jobs": 3,
            "task_cost": 0.05,
            "skew": 0.5,
        }
    return {
        "n_workers": 8,
        "n_batches": 4,
        "n_tasks": 16,
        "n_jobs": 8,
        "task_cost": 0.25,
        "skew": 0.5,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="sub-second workload (CI)")
    ap.add_argument(
        "--max-ratio",
        type=float,
        default=5.0,
        help="fail if live/predicted makespan exceeds this (sanity, generous)",
    )
    ap.add_argument("--out", type=pathlib.Path, default=ART / "runtime_bench.json")
    args = ap.parse_args()

    result = {
        "config": {"smoke": args.smoke, **_cfg(args.smoke)},
        "runtime": bench_runtime(_cfg(args.smoke)),
    }
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(result, indent=2))
    print(json.dumps(result, indent=2))

    run = result["runtime"]
    if not run["twin_replay_exact"]:
        raise SystemExit("FAIL: engine replay of the live trace is not exact")
    if run["live_over_predicted"] > args.max_ratio:
        raise SystemExit(
            f"FAIL: live/predicted makespan {run['live_over_predicted']} "
            f"exceeds --max-ratio {args.max_ratio}"
        )


if __name__ == "__main__":
    main()
