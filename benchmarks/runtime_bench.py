"""Live-runtime benchmark: real execution time vs the engine's prediction.

Runs a small redundant workload on the live asyncio master-worker runtime
(``repro.cluster.runtime``: real localhost sockets, thread workers, sleep
payloads) and compares three layers:

  * ``live``      -- wall-clock makespan and accounting measured by the
    master from its own grid-stamped trace;
  * ``replay``    -- the same trace replayed through the discrete-event
    engine (the digital twin): must match the live accounting *exactly*,
    so its row is a correctness canary, not an estimate;
  * ``predicted`` -- an a-priori ``ClusterEngine`` run with deterministic
    service times equal to the nominal batch costs: what the simulator
    promised before any real process ran.

``live_over_predicted`` is the headline ratio: how much real-world overhead
(socket round trips, event-loop scheduling, sleep granularity) inflates the
simulated makespan.  ``--smoke`` keeps the workload at a few hundred
milliseconds for CI, which uploads the JSON as an artifact; a ratio above
``--max-ratio`` (sanity, generous) fails the run.

The ``recovery`` section crashes the master halfway through the same
workload (journaled, abrupt -- no cleanup), rebuilds it with
``RuntimeMaster.recover`` from the write-ahead journal, resumes with fresh
workers, and reports ``recovery_overhead``: the crashed-and-recovered
makespan over the uninterrupted one.  ``check_bench_regression.py`` gates
that ratio (``BENCH_MAX_RECOVERY_OVERHEAD``); here it is recorded, and the
run fails hard only if the recovered journal does not replay exactly.
"""

from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import pathlib
import sys
import tempfile
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.cluster.master import ClusterEngine, Job  # noqa: E402
from repro.cluster.runtime import (  # noqa: E402
    LiveJob,
    Runtime,
    RuntimeMaster,
    read_journal,
    replay_trace,
    spawn_worker_thread,
    trace_accounting,
)
from repro.cluster.scenario import Scenario  # noqa: E402

ART = pathlib.Path(__file__).resolve().parent / "artifacts"


@dataclasses.dataclass
class _Deterministic:
    """Constant service time: the engine's a-priori model of a known cost."""

    value: float

    def sample_np(self, rng, shape):
        return self.value


def _workload(cfg: dict):
    """Uniform per-task costs so every batch of a job has one nominal cost
    (what the deterministic predictor needs), three jobs back to back."""
    n, b = cfg["n_workers"], cfg["n_batches"]
    jobs = [
        LiveJob(
            job_id=i,
            costs=(cfg["task_cost"],) * cfg["n_tasks"],
            skew=cfg["skew"],
            name=f"bench-{i}",
        )
        for i in range(cfg["n_jobs"])
    ]
    scenario = Scenario(n_batches=b, cancel_redundant=True)
    batch_cost = cfg["task_cost"] * (cfg["n_tasks"] // b)
    predicted = [
        Job(job_id=j.job_id, dist=_Deterministic(batch_cost), n_tasks=cfg["n_tasks"])
        for j in jobs
    ]
    return n, scenario, jobs, predicted


def bench_runtime(cfg: dict) -> dict:
    n, scenario, jobs, predicted_jobs = _workload(cfg)

    t0 = time.monotonic()
    report = Runtime(n, scenario).run(jobs, timeout_s=120.0)
    live_wall = time.monotonic() - t0

    live_makespan = max(r.finish for r in report.records)
    twin = replay_trace(report.trace, n, scenario)
    twin_exact = twin.accounting() == report.accounting()

    eng = ClusterEngine(
        n,
        seed=0,
        n_batches=scenario.n_batches,
        cancel_redundant=True,
        size_dependent=False,
    ).run(predicted_jobs)
    predicted_makespan = max(r.finish for r in eng.records)

    return {
        "n_workers": n,
        "n_jobs": len(jobs),
        "n_batches": scenario.n_batches,
        "replication": report.records[0].replication,
        "live_wall_s": round(live_wall, 4),
        "live_makespan_s": round(live_makespan, 4),
        "predicted_makespan_s": round(predicted_makespan, 4),
        "live_over_predicted": round(live_makespan / predicted_makespan, 4),
        "live_accounting": report.accounting(),
        "predicted_accounting": eng.accounting(),
        "twin_replay_exact": twin_exact,
        "n_trace_events": len(report.trace),
    }


async def _join_threads(threads, timeout_s: float = 10.0) -> None:
    # join worker threads off the event loop: a blocking join would stall the
    # loop callbacks that actually flush the master's socket closes, so the
    # workers would never see EOF and every join would burn its full timeout
    loop = asyncio.get_running_loop()
    for t in threads:
        await loop.run_in_executor(None, t.join, timeout_s)


def bench_recovery(cfg: dict) -> dict:
    """Crash the master mid-run, recover from the journal, and report the
    makespan inflation over the same workload run without a crash."""
    n, scenario, jobs, _ = _workload(cfg)
    tmp = pathlib.Path(tempfile.mkdtemp(prefix="runtime-bench-recovery-"))
    plain_journal = str(tmp / "plain.jsonl")
    crash_journal = str(tmp / "crash.jsonl")

    plain = Runtime(n, scenario, journal=plain_journal).run(jobs, timeout_s=120.0)
    plain_makespan = max(r.finish for r in plain.records)

    async def crashed_run():
        master = RuntimeMaster(n, scenario, journal=crash_journal)
        port = await master.start()
        threads = [spawn_worker_thread(master.host, port) for _ in range(n)]
        await master.wait_for_workers()
        run_task = asyncio.ensure_future(master.run(list(jobs), timeout_s=120.0))
        await asyncio.sleep(0.5 * plain_makespan)
        if run_task.done():  # workload beat the crash timer: report it as-is
            report = run_task.result()
        else:
            run_task.cancel()
            try:
                await run_task
            except asyncio.CancelledError:
                pass
            await master.crash()
            await _join_threads(threads)
            master = RuntimeMaster.recover(crash_journal)
            port = await master.start()
            threads = [spawn_worker_thread(master.host, port) for _ in range(n)]
            report = await master.resume(timeout_s=120.0)
        await master.close()
        await _join_threads(threads)
        return report

    t0 = time.monotonic()
    recovered = asyncio.run(crashed_run())
    recovered_wall = time.monotonic() - t0
    recovered_makespan = max(r.finish for r in recovered.records)

    events = read_journal(crash_journal)
    twin = replay_trace(events)
    twin_exact = twin.accounting() == recovered.accounting() == trace_accounting(events)
    return {
        "plain_makespan_s": round(plain_makespan, 4),
        "recovered_makespan_s": round(recovered_makespan, 4),
        "recovery_overhead": round(recovered_makespan / plain_makespan, 4),
        "recovered_wall_s": round(recovered_wall, 4),
        "crash_exercised": any(e["ev"] == "recover" for e in events),
        "twin_replay_exact": twin_exact,
        "n_journal_events": len(events),
    }


def _cfg(smoke: bool) -> dict:
    if smoke:
        return {
            "n_workers": 4,
            "n_batches": 2,
            "n_tasks": 4,
            "n_jobs": 3,
            "task_cost": 0.05,
            "skew": 0.5,
        }
    return {
        "n_workers": 8,
        "n_batches": 4,
        "n_tasks": 16,
        "n_jobs": 8,
        "task_cost": 0.25,
        "skew": 0.5,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="sub-second workload (CI)")
    ap.add_argument(
        "--max-ratio",
        type=float,
        default=5.0,
        help="fail if live/predicted makespan exceeds this (sanity, generous)",
    )
    ap.add_argument("--out", type=pathlib.Path, default=ART / "runtime_bench.json")
    args = ap.parse_args()

    result = {
        "config": {"smoke": args.smoke, **_cfg(args.smoke)},
        "runtime": bench_runtime(_cfg(args.smoke)),
        "recovery": bench_recovery(_cfg(args.smoke)),
    }
    args.out.parent.mkdir(parents=True, exist_ok=True)
    args.out.write_text(json.dumps(result, indent=2))
    print(json.dumps(result, indent=2))

    run = result["runtime"]
    if not run["twin_replay_exact"]:
        raise SystemExit("FAIL: engine replay of the live trace is not exact")
    if run["live_over_predicted"] > args.max_ratio:
        raise SystemExit(
            f"FAIL: live/predicted makespan {run['live_over_predicted']} "
            f"exceeds --max-ratio {args.max_ratio}"
        )
    if not result["recovery"]["twin_replay_exact"]:
        raise SystemExit("FAIL: engine replay of the crashed-and-recovered journal is not exact")


if __name__ == "__main__":
    main()
