"""Roofline derivation from the dry-run artifacts (EXPERIMENTS.md §Roofline).

For every single-pod cell:
    compute term    = flops_per_device / 197 TFLOP/s       (bf16 MXU peak)
    memory term     = hbm_bytes_per_device / 819 GB/s
    collective term = ici_wire_bytes_per_device / 50 GB/s
                      (+ dcn bytes / 25 GB/s on multi-pod cells)
    MODEL_FLOPS     = {6,2} * N(_active) * tokens  (train / inference)
    usefulness      = MODEL_FLOPS / (flops_per_device * n_devices)

All per-device quantities are loop-weighted (launch/hlo_stats.py).  The
dominant term is the bottleneck; `roofline_fraction` = dominant-term share
of an ideal perfectly-overlapped step (model_compute_time / dominant_term).
"""
from __future__ import annotations

import glob
import json
import pathlib
from typing import Dict, List

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link
DCN_BW = 25e9  # bytes/s / host (pod axis)

ART = pathlib.Path(__file__).resolve().parent / "artifacts"
DRYRUN = ART / "dryrun"


def _kind_factor(kind: str) -> int:
    return 6 if kind == "train" else 2


# arch metadata for the kernel-adjustment (padded heads on the 16-wide TP axis)
_ATTN = {
    # arch: (n_layers, padded_heads, window_or_None)
    "qwen2-1.5b": (28, 16, None),
    "yi-9b": (48, 32, None),
    "gemma-7b": (28, 16, None),
    "starcoder2-3b": (30, 32, None),
    "hubert-xlarge": (48, 16, None),
    "recurrentgemma-2b": (9, 16, 2048),  # attention layers only (1 in 3)
    "qwen2-vl-7b": (28, 32, None),
    "dbrx-132b": (40, 48, None),
    "qwen3-moe-235b-a22b": (94, 64, None),
    "mamba2-2.7b": (0, 0, None),
}


def _attn_score_traffic_per_dev(r: Dict) -> float:
    """HBM bytes the jnp attention path spends materializing score blocks.

    The Pallas flash kernel keeps s/p in VMEM, so the TPU-target memory term
    subtracts this: ~16 B per (query token x key pos x local head) per pass
    (s and p, fp32, written+read) x 3 passes for train (fwd/remat/bwd), 1
    for prefill; decode is negligible.
    """
    arch = r["arch"]
    layers, heads_pad, window = _ATTN.get(arch, (0, 0, None))
    if not layers or r["kind"] == "decode":
        return 0.0
    seq = {"train_4k": 4096, "prefill_32k": 32768, "long_500k": 524288}[r["shape"]]
    s_kv = min(seq, window) if window else seq
    tokens_dev = r["tokens_per_step"] / r["n_devices"]
    heads_local = max(heads_pad // 16, 1)
    passes = 3 if r["kind"] == "train" else 1
    return 16.0 * tokens_dev * s_kv * heads_local * layers * passes


def load_cells(mesh_prefix: str = "singlepod", pattern: str = "*") -> List[Dict]:
    cells = []
    for f in sorted(glob.glob(str(DRYRUN / f"{mesh_prefix}_{pattern}.json"))):
        r = json.loads(pathlib.Path(f).read_text())
        # exact mesh match: exclude tagged §Perf variant cells from the
        # baseline table (they load via explicit pattern instead)
        if r.get("ok") and r.get("mesh") == mesh_prefix:
            cells.append(r)
    return cells


def roofline_row(r: Dict) -> Dict:
    hs = r["hlo_stats"]
    n_dev = r["n_devices"]
    flops_dev = hs["flops"]
    compute_t = flops_dev / PEAK_FLOPS
    memory_raw_t = hs["hbm_bytes"] / HBM_BW
    # TPU-target adjustment: flash-kernel keeps attention scores in VMEM
    adj_bytes = min(_attn_score_traffic_per_dev(r), hs["hbm_bytes"] * 0.9)
    memory_t = (hs["hbm_bytes"] - adj_bytes) / HBM_BW
    ici = sum(c["ici_bytes"] for c in hs["collectives"].values())
    dcn = sum(c["dcn_bytes"] for c in hs["collectives"].values())
    collective_t = ici / ICI_BW + dcn / DCN_BW

    n_params = (
        r["active_params_estimate"] if r["kind"] != "train" else r["params_estimate"]
    )
    if r["kind"] == "train":
        # MoE models train on active params only
        n_params = r["active_params_estimate"]
    model_flops = _kind_factor(r["kind"]) * n_params * r["tokens_per_step"]
    hlo_flops_global = flops_dev * n_dev
    usefulness = model_flops / hlo_flops_global if hlo_flops_global else 0.0

    terms = {"compute": compute_t, "memory": memory_t, "collective": collective_t}
    dominant = max(terms, key=terms.get)
    ideal_compute = model_flops / (n_dev * PEAK_FLOPS)
    step_time = max(terms.values())  # perfectly-overlapped bound
    return {
        "arch": r["arch"],
        "shape": r["shape"],
        "mesh": r["mesh"],
        "microbatches": r.get("microbatches"),
        "compute_s": compute_t,
        "memory_s": memory_t,
        "memory_raw_s": memory_raw_t,  # before the flash-kernel VMEM adjustment
        "collective_s": collective_t,
        "dcn_s": dcn / DCN_BW,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_flops_global,
        "usefulness": usefulness,
        "roofline_fraction": ideal_compute / step_time if step_time else 0.0,
        "hbm_gb_per_dev": (
            r["memory_analysis"].get("argument_size_in_bytes", 0)
            + r["memory_analysis"].get("temp_size_in_bytes", 0)
            + r["memory_analysis"].get("output_size_in_bytes", 0)
            - r["memory_analysis"].get("alias_size_in_bytes", 0)
        ) / 1e9,
    }


def suggestion(row: Dict) -> str:
    d = row["dominant"]
    if d == "collective":
        return (
            "cut collective bytes: fewer grad-accumulation param re-gathers "
            "(SP/ZeRO stage change) or rebalance TP vs DP for this model size"
        )
    if d == "memory":
        return (
            "cut HBM traffic: KV-cache aliasing/sharding (decode) or "
            "larger fused blocks / fewer remat re-reads (train)"
        )
    return "compute-bound: reduce padded-head / causal-mask waste, fuse attention"


def table(mesh_prefix: str = "singlepod", pattern: str = "*") -> List[Dict]:
    return [roofline_row(r) for r in load_cells(mesh_prefix, pattern)]


def markdown(rows: List[Dict]) -> str:
    hdr = (
        "| arch | shape | mb | compute s | memory s | collective s | dominant | "
        "MODEL/HLO flops | roofline frac | HBM GB/dev |\n|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['microbatches'] or '-'} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} | {r['collective_s']:.3f} "
            f"| **{r['dominant']}** | {r['usefulness']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {r['hbm_gb_per_dev']:.1f} |"
        )
    return hdr + "\n".join(lines) + "\n"


def run_all():
    rows = table()
    out = ART / "roofline_singlepod.json"
    out.write_text(json.dumps(rows, indent=2))
    (ART / "roofline_singlepod.md").write_text(markdown(rows))
    worst = min(rows, key=lambda r: r["roofline_fraction"]) if rows else None
    bench_rows = []
    for r in rows:
        bench_rows.append((
            f"roofline_{r['arch']}_{r['shape']}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"dom={r['dominant']};frac={r['roofline_fraction']:.3f}",
        ))
    if worst:
        bench_rows.append((
            "roofline_worst_cell", 0.0,
            f"{worst['arch']}/{worst['shape']} frac={worst['roofline_fraction']:.4f}",
        ))
    return bench_rows
